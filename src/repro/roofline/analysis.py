"""Roofline terms from a compiled (SPMD-partitioned) XLA module.

The container is CPU-only, so per the brief the three roofline terms for the
TPU v5e target are *derived* from the compiled artifact:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = collective_bytes_per_device / ICI_link_bandwidth

Empirically verified in this environment (see EXPERIMENTS.md §Dry-run):
``compiled.cost_analysis()`` reports **per-device** flops/bytes after GSPMD
partitioning, and ``compiled.as_text()`` prints every collective with its
result shape and replica groups — collective_bytes is not in cost_analysis
and is parsed from the HLO text here.

Two collective-bytes numbers are produced:
* ``operand`` — the brief's definition: sum of operand sizes of every
  collective op (per device).
* ``wire``    — ring-schedule wire traffic per device (what actually crosses
  links): all-reduce 2·S·(k-1)/k, all-gather/all-to-all S·(k-1)/k,
  reduce-scatter S·(k-1)/k of the *full* (pre-scatter) size, permute S.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

# ---------------------------------------------------------------------------
# Hardware model (TPU v5e, per the brief)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float  # per chip, bf16
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link
    hbm_bytes: float  # capacity per chip


HW_V5E = Hardware(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 2**30,
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# result shapes before the op name, e.g.  %x = f32[256,1024]{1,0} all-reduce(
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    op_counts: dict
    operand_bytes: float  # per device, brief's definition
    wire_bytes: float  # per device, ring estimate
    by_op_operand: dict
    lines: list  # (kind, bytes_result, group_size) per op, for debugging


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    op_counts: dict[str, int] = {}
    by_op: dict[str, float] = {}
    operand_total = 0.0
    wire_total = 0.0
    lines = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.startswith("//") or "=" not in line:
            continue
        m_op = None
        lhs = None
        for kind in _COLLECTIVES:
            # the op *application* is "<shapes> <kind>[...](operands" after
            # the '='; matching on the rhs avoids the SSA register name,
            # which usually also contains the op name.
            m = re.search(rf"=\s*(.+?)\s*{kind}(-start)?[.\d]*\(", line)
            if m is not None:
                if m.group(2):  # -start: payload counted here, -done skipped
                    pass
                if re.search(rf"{kind}-done", line):
                    m_op = None
                    break
                m_op = kind
                lhs = m.group(1)
                break
        if m_op is None or lhs is None:
            continue
        shapes = _SHAPE_RE.findall(lhs)
        result_bytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        if result_bytes == 0:
            continue
        # participants per group
        k = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            k = int(mg.group(2))
        else:
            mg2 = _GROUPS_LIST_RE.search(line)
            if mg2:
                k = len(mg2.group(1).split(","))
        if m_op == "all-reduce":
            operand = result_bytes
            wire = 2.0 * result_bytes * (k - 1) / max(k, 1)
        elif m_op == "all-gather":
            operand = result_bytes / max(k, 1)
            wire = result_bytes * (k - 1) / max(k, 1)
        elif m_op == "reduce-scatter":
            operand = result_bytes * k
            wire = result_bytes * (k - 1)
        elif m_op == "all-to-all":
            operand = result_bytes
            wire = result_bytes * (k - 1) / max(k, 1)
        else:  # collective-permute
            operand = result_bytes
            wire = result_bytes
        op_counts[m_op] = op_counts.get(m_op, 0) + 1
        by_op[m_op] = by_op.get(m_op, 0.0) + operand
        operand_total += operand
        wire_total += wire
        lines.append((m_op, result_bytes, k))
    return CollectiveStats(op_counts, operand_total, wire_total, by_op, lines)


# ---------------------------------------------------------------------------
# Full analysis of one compiled cell
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    # per-device quantities
    flops: float
    hbm_bytes: float
    collective_operand_bytes: float
    collective_wire_bytes: float
    collective_ops: dict
    # derived times (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    # usefulness
    model_flops_global: float
    useful_ratio: float  # MODEL_FLOPS / (flops * num_devices)
    # memory footprint (per device)
    arg_bytes: float
    temp_bytes: float
    out_bytes: float

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    num_devices: int,
    model_flops_global: float,
    hw: Hardware = HW_V5E,
) -> RooflineResult:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    hbm_bytes = float(ca.get("bytes accessed", 0.0))
    stats = collective_bytes_from_hlo(compiled.as_text())

    t_compute = flops / hw.peak_flops
    t_memory = hbm_bytes / hw.hbm_bw
    t_collective = stats.operand_bytes / hw.ici_bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    bottleneck = max(terms, key=terms.get)

    ma = compiled.memory_analysis()
    arg_b = float(getattr(ma, "argument_size_in_bytes", 0) or 0)
    tmp_b = float(getattr(ma, "temp_size_in_bytes", 0) or 0)
    out_b = float(getattr(ma, "output_size_in_bytes", 0) or 0)

    total_flops = flops * num_devices
    useful = model_flops_global / total_flops if total_flops else 0.0
    return RooflineResult(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        num_devices=num_devices,
        flops=flops,
        hbm_bytes=hbm_bytes,
        collective_operand_bytes=stats.operand_bytes,
        collective_wire_bytes=stats.wire_bytes,
        collective_ops=stats.op_counts,
        t_compute=t_compute,
        t_memory=t_memory,
        t_collective=t_collective,
        bottleneck=bottleneck,
        model_flops_global=model_flops_global,
        useful_ratio=useful,
        arg_bytes=arg_b,
        temp_bytes=tmp_b,
        out_bytes=out_b,
    )


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs for the cell (global, one step).

    train: 6·N·D (fwd+bwd);  prefill: 2·N·D;  decode: 2·N·D with D = one
    token per sequence.  N = active params (MoE-aware).  Attention quadratic
    term added explicitly for train/prefill; decode adds the KV-read dot cost.
    """
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    d_attn = cfg.num_heads * hd

    def n_attn_layers() -> int:
        if cfg.family == "hybrid":
            return cfg.num_layers // max(cfg.hybrid_attn_every, 1)
        if cfg.family == "ssm":
            return 0
        return cfg.num_layers

    def _encdec_split() -> tuple[float, float]:
        """enc-dec: each token passes only its side's stack.  Returns
        (N_weighted_by_tokens, attn_token_seq_product) for S/2 + S/2."""
        # rough split: embedding+head on decoder side; layer params ~ half each
        n_total = cfg.param_count()
        embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        n_layers_all = n_total - embed
        n_enc = n_layers_all * cfg.encoder_layers / (cfg.encoder_layers + 1.5 * cfg.decoder_layers)
        n_dec = n_layers_all - n_enc + embed
        s_half = S / 2
        n_eff = (n_enc + n_dec) / 2  # per-token average over both streams
        return n_eff, s_half

    if shape.mode == "train":
        tokens = B * S
        if cfg.family == "encdec":
            n_eff, s_half = _encdec_split()
            base = 6.0 * n_eff * tokens
            # enc self (full) + dec self (causal) + cross at s_half each
            base += 6.0 * cfg.encoder_layers * d_attn * s_half * (B * s_half) * 2
            base += 6.0 * cfg.decoder_layers * d_attn * (s_half / 2 + s_half) * (B * s_half) * 2
            return base
        base = 6.0 * n_active * tokens
        # causal attention: fwd 2·S·d_attn per token (QKᵀ+PV over S/2 keys),
        # train = 3x fwd (PaLM appendix convention: 12·(S/2)·d_attn)
        base += 6.0 * n_attn_layers() * d_attn * S * tokens
        return base
    if shape.mode == "prefill":
        tokens = B * S
        if cfg.family == "encdec":
            n_eff, s_half = _encdec_split()
            return 2.0 * n_eff * tokens + 2.0 * (cfg.encoder_layers + 1.5 * cfg.decoder_layers) * d_attn * s_half * (B * s_half) * 2
        return 2.0 * n_active * tokens + 2.0 * n_attn_layers() * d_attn * S * tokens
    # decode: one token per sequence + attention reads over the full cache
    tokens = B * 1
    flops = 2.0 * n_active * tokens
    flops += 4.0 * n_attn_layers() * d_attn * S * tokens  # QKᵀ + PV vs S keys
    return flops
