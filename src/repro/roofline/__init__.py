"""Roofline analysis from compiled dry-run artifacts (no hardware needed)."""

from repro.roofline.analysis import (  # noqa: F401
    HW_V5E,
    RooflineResult,
    analyze_compiled,
    collective_bytes_from_hlo,
)
