"""Structured observability for the platform: tracing, metrics, export.

Three pieces, deliberately dependency-free (stdlib only) so every layer
of the platform — client, driver protocol, isolation supervisor, chaos
controller, serving routers — can emit telemetry without import cycles:

- ``obs.trace``  — hierarchical spans with ``(job, attempt, span)`` ids
  and a pluggable clock (wall or the concurrency harness's virtual
  clock), mergeable across the process-isolation boundary.
- ``obs.metrics`` — a lock-safe counter/gauge/histogram registry
  snapshotted into ``JobReport.metrics`` and the platform wait result.
- ``obs.export`` — JSONL dump, Chrome ``trace_event`` conversion
  (Perfetto-loadable), and a per-stage p50/p99 text report.
"""

from repro.obs.trace import CHILD_SPAN_BASE, Span, Tracer
from repro.obs.metrics import MetricsRegistry, stage_summary
from repro.obs.export import (
    read_jsonl,
    text_report,
    to_chrome_trace,
    validate_chrome,
    write_jsonl,
)

__all__ = [
    "CHILD_SPAN_BASE",
    "Span",
    "Tracer",
    "MetricsRegistry",
    "stage_summary",
    "read_jsonl",
    "text_report",
    "to_chrome_trace",
    "validate_chrome",
    "write_jsonl",
]
