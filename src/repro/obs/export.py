"""Trace export: JSONL dump, Chrome ``trace_event``, text report.

The JSONL dump is the interchange format (one span dict per line,
sorted and key-stable, so identical traces produce identical bytes).
``to_chrome_trace`` converts it to the Chrome/Perfetto ``trace_event``
JSON — open ``https://ui.perfetto.dev`` and drop the file on it; each
job becomes a process track, each attempt (worker/container) a thread
track.  ``text_report`` is the terminal view: a per-stage p50/p99
latency table plus a per-job critical-path summary.
"""

from __future__ import annotations

import json
from typing import Iterable, Optional

from repro.obs.metrics import stage_summary
from repro.obs.trace import Span


def _as_spans(spans: Iterable) -> list[Span]:
    return [s if isinstance(s, Span) else Span.from_dict(s) for s in spans]


def write_jsonl(spans: Iterable, path: str) -> int:
    """One span per line, sorted by (t0, id) — deterministic bytes."""
    out = sorted(_as_spans(spans), key=lambda s: (s.t0, s.job, s.attempt, s.span))
    with open(path, "w") as f:
        for s in out:
            f.write(json.dumps(s.to_dict(), sort_keys=True) + "\n")
    return len(out)


def read_jsonl(path: str) -> list[Span]:
    spans = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                spans.append(Span.from_dict(json.loads(line)))
    return spans


def _track(s: Span) -> str:
    """Thread-track key within a job's process track."""
    if "track" in s.tags:
        return str(s.tags["track"])
    if s.attempt:
        return f"attempt {s.attempt}"
    return "lifecycle"


def to_chrome_trace(spans: Iterable) -> dict:
    """Chrome ``trace_event`` JSON: ``{"traceEvents": [...]}``.

    Tracks: pid per job (``process_name`` metadata), tid per attempt /
    worker / cell within it (``thread_name``).  Spans become complete
    ("X") events with microsecond ts/dur; span events become instants
    ("i").  Open-ended spans (a killed worker's) export with dur 0 and
    an ``unclosed`` arg rather than being dropped.
    """
    spans = sorted(_as_spans(spans), key=lambda s: (s.t0, s.job, s.attempt, s.span))
    jobs = sorted({s.job for s in spans})
    pid = {job: i + 1 for i, job in enumerate(jobs)}
    tid: dict[tuple, int] = {}
    for s in spans:
        key = (s.job, _track(s))
        if key not in tid:
            tid[key] = len([k for k in tid if k[0] == s.job]) + 1

    events: list[dict] = []
    for job in jobs:
        events.append({
            "name": "process_name", "ph": "M", "pid": pid[job], "tid": 0,
            "ts": 0, "args": {"name": job},
        })
    for (job, track), t in sorted(tid.items(), key=lambda kv: (kv[0][0], kv[1])):
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid[job], "tid": t,
            "ts": 0, "args": {"name": track},
        })

    t_min = min((s.t0 for s in spans), default=0.0)
    for s in spans:
        p, t = pid[s.job], tid[(s.job, _track(s))]
        dur = max(((s.t1 if s.t1 is not None else s.t0) - s.t0) * 1e6, 0.0)
        args = {k: v for k, v in s.tags.items()}
        args["span_id"] = f"{s.job}/{s.attempt}/{s.span}"
        if s.parent is not None:
            args["parent"] = "/".join(map(str, s.parent))
        if s.t1 is None:
            args["unclosed"] = True
        events.append({
            "name": s.name, "ph": "X", "pid": p, "tid": t,
            "ts": (s.t0 - t_min) * 1e6, "dur": dur, "args": args,
        })
        for (te, name, tags) in s.events:
            events.append({
                "name": name, "ph": "i", "s": "t", "pid": p, "tid": t,
                "ts": (te - t_min) * 1e6, "args": dict(tags),
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome(trace: dict) -> None:
    """Raise ``ValueError`` on trace_event schema violations.

    Checks the invariants Perfetto's importer relies on: top-level
    ``traceEvents`` list, every event carries name/ph/pid/tid and a
    numeric non-negative ts, complete events carry a non-negative dur,
    and every (pid, tid) used by an event is named by metadata.
    """
    if not isinstance(trace, dict) or not isinstance(trace.get("traceEvents"), list):
        raise ValueError("trace must be a dict with a traceEvents list")
    named_pids, named_tids = set(), set()
    for ev in trace["traceEvents"]:
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event missing {field!r}: {ev!r}")
        if ev["ph"] not in ("X", "M", "i", "B", "E"):
            raise ValueError(f"unknown phase {ev['ph']!r}")
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            raise ValueError(f"bad ts in {ev!r}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"complete event missing dur: {ev!r}")
        if ev["ph"] == "M":
            if ev["name"] == "process_name":
                named_pids.add(ev["pid"])
            elif ev["name"] == "thread_name":
                named_tids.add((ev["pid"], ev["tid"]))
    for ev in trace["traceEvents"]:
        if ev["ph"] in ("X", "i"):
            if ev["pid"] not in named_pids:
                raise ValueError(f"pid {ev['pid']} has no process_name metadata")
            if (ev["pid"], ev["tid"]) not in named_tids:
                raise ValueError(
                    f"tid {ev['tid']} in pid {ev['pid']} has no thread_name metadata"
                )


def text_report(spans: Iterable, job: Optional[str] = None) -> str:
    """Per-stage p50/p99 table + per-job critical-path summary."""
    spans = _as_spans(spans)
    if job is not None:
        spans = [s for s in spans if s.job == job]
    if not spans:
        return "(no spans)"

    stages = stage_summary(spans)
    lines = ["stage latency (s)"]
    w = max([len("stage")] + [len(n) for n in stages])
    lines.append(f"{'stage':<{w}}  {'count':>6}  {'p50':>9}  {'p99':>9}  {'total':>9}")
    for name, st in stages.items():
        lines.append(
            f"{name:<{w}}  {st['count']:>6}  {st['p50_s']:>9.4f}  "
            f"{st['p99_s']:>9.4f}  {st['total_s']:>9.3f}"
        )

    lines.append("")
    lines.append("critical path by job")
    by_job: dict[str, list] = {}
    for s in spans:
        by_job.setdefault(s.job, []).append(s)
    for jname in sorted(by_job):
        js = by_job[jname]
        roots = [s for s in js if s.name == "job"]
        wall = roots[0].duration_s if roots else max(
            (s.duration_s for s in js if s.t1 is not None), default=0.0
        )
        attempts = max((s.attempt for s in js), default=0)
        chaos = sum(
            1 for s in js for (_, n, _) in s.events if n.startswith("chaos[")
        )
        # dominant stage = stage with the largest closed-span total,
        # excluding the all-enclosing job/attempt wrappers
        totals: dict[str, float] = {}
        for s in js:
            if s.t1 is not None and s.name not in ("job", "attempt", "isolated_run"):
                totals[s.name] = totals.get(s.name, 0.0) + s.duration_s
        if totals:
            dom = max(sorted(totals), key=lambda n: totals[n])
            dom_txt = f"dominant stage {dom} ({totals[dom]:.3f}s)"
        else:
            dom_txt = "no stage spans"
        chaos_txt = f", {chaos} chaos events" if chaos else ""
        # serving fast-path counters, if the serve driver stamped them
        # onto its attempt spans (speculation / prefix sharing / fused
        # chunked prefill activity, summed over attempts)
        fast: dict[str, int] = {}
        for s in js:
            for (_, n, tags) in s.events:
                if n == "serve.fastpath":
                    for k, v in tags.items():
                        fast[k] = fast.get(k, 0) + int(v)
        fast_txt = (
            ", fastpath " + " ".join(f"{k}={v}" for k, v in sorted(fast.items()))
        ) if fast else ""
        lines.append(
            f"  {jname}: wall {wall:.3f}s over {attempts} attempt(s), "
            f"{dom_txt}{chaos_txt}{fast_txt}"
        )
    return "\n".join(lines)
