"""Hierarchical spans with ``(job, attempt, span)`` ids.

A span is one timed unit of platform work — a job's lifecycle, one
attempt on a container, one ``CheckpointToken.checkpoint()`` round-trip,
a SIGTERM→SIGKILL enforcement ladder, one served request.  Spans nest
via parent ids rather than thread-local context because platform work
hops threads (dispatcher → worker) and processes (supervisor → isolated
child); the id triple is stable across both.

The tracer's clock is pluggable: production uses ``time.monotonic``,
the deterministic concurrency tier injects its ``VirtualClock`` so two
seeded runs produce *identical* traces (``sequence()`` renders the
timestamp-free canonical form that the byte-identity proof compares).

Cross-process spans: the isolation supervisor stamps the parent span id
and clock origin into the bootstrap frame; the child builds its own
tracer with ``seq0=CHILD_SPAN_BASE`` so its span ids can never collide
with parent-side ids for the same (job, attempt), and ships its span
dicts back on the terminal IPC frame for ``merge()``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable, Iterable, Optional

# Child-process tracers number spans from here so supervisor-side spans
# (bounded by checkpoint count, far below 2**20) never collide with
# child-side spans for the same (job, attempt).  Fixed, so numbering
# stays deterministic.
CHILD_SPAN_BASE = 1 << 20


@dataclasses.dataclass
class Span:
    """One timed unit of work.  Identified by ``(job, attempt, span)``."""

    job: str
    attempt: int
    span: int
    name: str
    t0: float
    t1: Optional[float] = None
    parent: Optional[tuple] = None  # (job, attempt, span) of enclosing span
    tags: dict = dataclasses.field(default_factory=dict)
    # (t, name, tags) point-in-time annotations, e.g. chaos injections
    events: list = dataclasses.field(default_factory=list)

    @property
    def span_id(self) -> tuple:
        return (self.job, self.attempt, self.span)

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def to_dict(self) -> dict:
        return {
            "job": self.job,
            "attempt": self.attempt,
            "span": self.span,
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "parent": list(self.parent) if self.parent is not None else None,
            "tags": dict(self.tags),
            "events": [[t, n, dict(tags)] for (t, n, tags) in self.events],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(
            job=d["job"],
            attempt=int(d["attempt"]),
            span=int(d["span"]),
            name=d["name"],
            t0=float(d["t0"]),
            t1=None if d.get("t1") is None else float(d["t1"]),
            parent=tuple(d["parent"]) if d.get("parent") else None,
            tags=dict(d.get("tags") or {}),
            events=[(float(t), n, dict(tags)) for t, n, tags in d.get("events") or []],
        )

    def canonical(self) -> str:
        """Timestamp-free rendering for determinism proofs.

        Includes structure (id, name, parent), non-float tags, and event
        names — everything that must match bit-for-bit across two seeded
        runs — and excludes wall-clock-derived values (timestamps,
        duration tags) that legitimately vary.
        """
        tags = ",".join(
            f"{k}={self.tags[k]}"
            for k in sorted(self.tags)
            if isinstance(self.tags[k], (str, int, bool))
            and not isinstance(self.tags[k], float)
        )
        evs = ",".join(n for (_, n, _) in self.events)
        par = "-" if self.parent is None else "/".join(map(str, self.parent))
        return (
            f"{self.job}/{self.attempt}/{self.span} {self.name}"
            f" <- {par} {{{tags}}} [{evs}]"
        )


class Tracer:
    """Thread-safe span factory and store.

    When ``enabled=False`` every method is a cheap no-op (``start``
    returns ``None`` and the mutators tolerate ``None``), so hot paths
    can call unconditionally — this is the tracing-off benchmark leg.
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.monotonic,
        *,
        enabled: bool = True,
        seq0: int = 1,
    ):
        self._clock = clock
        self.enabled = enabled
        self._seq0 = seq0
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._seq: dict[tuple, int] = {}  # (job, attempt) -> next span seq

    def now(self) -> float:
        return self._clock()

    def start(
        self,
        name: str,
        *,
        job: str,
        attempt: int = 0,
        parent: Any = None,
        t: Optional[float] = None,
        **tags: Any,
    ) -> Optional[Span]:
        if not self.enabled:
            return None
        if isinstance(parent, Span):
            parent = parent.span_id
        elif parent is not None:
            parent = tuple(parent)
        t0 = self._clock() if t is None else t
        with self._lock:
            key = (job, attempt)
            seq = self._seq.get(key, self._seq0)
            self._seq[key] = seq + 1
            sp = Span(
                job=job, attempt=attempt, span=seq, name=name,
                t0=t0, parent=parent, tags=dict(tags),
            )
            self._spans.append(sp)
        return sp

    def end(self, span: Optional[Span], t: Optional[float] = None) -> None:
        if span is None or not self.enabled:
            return
        t1 = self._clock() if t is None else t
        with self._lock:
            span.t1 = t1

    def event(
        self,
        span: Optional[Span],
        name: str,
        t: Optional[float] = None,
        **tags: Any,
    ) -> None:
        if span is None or not self.enabled:
            return
        te = self._clock() if t is None else t
        with self._lock:
            span.events.append((te, name, tags))

    def tag(self, span: Optional[Span], **tags: Any) -> None:
        if span is None or not self.enabled:
            return
        with self._lock:
            span.tags.update(tags)

    @contextlib.contextmanager
    def span(self, name: str, **kw: Any):
        sp = self.start(name, **kw)
        try:
            yield sp
        finally:
            self.end(sp)

    def spans(self, job: Optional[str] = None) -> list[Span]:
        with self._lock:
            if job is None:
                return list(self._spans)
            return [s for s in self._spans if s.job == job]

    def to_dicts(self, job: Optional[str] = None) -> list[dict]:
        return [s.to_dict() for s in self.spans(job)]

    def merge(self, records: Iterable[dict]) -> None:
        """Ingest span dicts from another tracer (an isolated child)."""
        if not self.enabled:
            return
        with self._lock:
            for r in records:
                sp = Span.from_dict(r)
                self._spans.append(sp)
                key = (sp.job, sp.attempt)
                nxt = self._seq.get(key, self._seq0)
                if sp.span >= nxt:
                    self._seq[key] = sp.span + 1

    def sequence(self, job: Optional[str] = None) -> list[str]:
        """Canonical timestamp-free span sequence, sorted by id.

        Sorting by ``(job, attempt, span)`` makes the rendering
        independent of thread interleaving in span *storage* order;
        with a deterministic executor two seeded runs are byte-equal.
        """
        spans = sorted(self.spans(job), key=lambda s: (s.job, s.attempt, s.span))
        return [s.canonical() for s in spans]
