"""Lock-safe counter/gauge/histogram registry.

One registry per ``Platform`` (``platform.obs``).  Drivers reach it
through the ``CheckpointToken`` the executor binds, so workload code
never imports the client.  Histograms keep raw observations (platform
runs are bounded — thousands of samples, not millions) and compute
percentiles at ``snapshot()`` time; snapshots are plain dicts of
scalars, safe to stash in ``JobReport.metrics``.

Catalog (what the platform itself records):

======================  =========  =========================================
name                    type       meaning
======================  =========  =========================================
pool_utilization        gauge/hist fraction of devices claimed at dispatch
queue_wait_s.<kind>     histogram  submit/requeue -> worker start, per kind
checkpoint_s.<kind>     histogram  full checkpoint() round-trip, per kind
serve_queue_wait_s      histogram  request arrival -> admission
serve_prefill_s         histogram  per-request prefill compute
serve_decode_step_s     histogram  one engine decode step
serve_ttft_s            histogram  arrival -> first token
serve_tokens_per_s      histogram  per-attempt decode throughput
serve_spec_proposed     counter    speculative draft tokens proposed
serve_spec_accepted     counter    draft tokens accepted by verification
serve_prefix_hits       counter    admissions that hit the prefix index
serve_pages_shared      counter    K/V pages attached via prefix sharing
serve_prefill_chunks    counter    prefill chunks fused into decode steps
deadline_miss           counter    outputs delivered past their budget
deadline_shed           counter    requests shed at deadline admission
preempts / resumes      counter    scheduler preemption round-trips
resize_offers           counter    elastic offers posted
resizes_committed       counter    offers accepted + re-granted
retries                 counter    container-failure resubmits
cancels                 counter    cancel() requests
jobs_<state>            counter    terminal states (jobs_done, ...)
chaos_injections[.kind] counter    chaos faults actually injected
======================  =========  =========================================
"""

from __future__ import annotations

import threading
from typing import Iterable, Optional

from repro.obs.trace import Span


def percentile(sorted_vals: list, q: float) -> float:
    """Nearest-rank-with-interpolation percentile of a pre-sorted list."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return float(sorted_vals[0])
    pos = (len(sorted_vals) - 1) * q
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return float(sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac)


class MetricsRegistry:
    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, list] = {}

    def inc(self, name: str, v: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + v

    def gauge(self, name: str, v: float) -> None:
        with self._lock:
            self._gauges[name] = float(v)

    def observe(self, name: str, v: float) -> None:
        with self._lock:
            self._hists.setdefault(name, []).append(float(v))

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def snapshot(self) -> dict:
        """Scalars-only snapshot: counters, gauges, histogram summaries."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: list(v) for k, v in self._hists.items()}
        out = {"counters": counters, "gauges": gauges, "histograms": {}}
        for name, vals in sorted(hists.items()):
            vals.sort()
            out["histograms"][name] = {
                "count": len(vals),
                "total": float(sum(vals)),
                "mean": float(sum(vals) / len(vals)) if vals else 0.0,
                "p50": percentile(vals, 0.50),
                "p99": percentile(vals, 0.99),
                "max": float(vals[-1]) if vals else 0.0,
            }
        return out

    def dump(self) -> dict:
        """Raw state, for shipping across a process boundary."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: list(v) for k, v in self._hists.items()},
            }

    def merge(self, dump: dict) -> None:
        """Fold another registry's ``dump()`` into this one."""
        with self._lock:
            for k, v in (dump.get("counters") or {}).items():
                self._counters[k] = self._counters.get(k, 0.0) + v
            for k, v in (dump.get("gauges") or {}).items():
                self._gauges[k] = float(v)
            for k, vals in (dump.get("histograms") or {}).items():
                self._hists.setdefault(k, []).extend(vals)


def stage_summary(spans: Iterable[Span], top: Optional[int] = None) -> dict:
    """Per-stage duration summary over closed spans.

    Returns ``{stage: {count, total_s, p50_s, p99_s}}`` — the compact
    per-job telemetry stashed under ``JobReport.metrics["obs"]``.
    """
    by_name: dict[str, list] = {}
    for s in spans:
        if s.t1 is None:
            continue
        by_name.setdefault(s.name, []).append(s.duration_s)
    out = {}
    names = sorted(by_name, key=lambda n: -sum(by_name[n]))
    if top is not None:
        names = names[:top]
    for name in sorted(names):
        durs = sorted(by_name[name])
        out[name] = {
            "count": len(durs),
            "total_s": float(sum(durs)),
            "p50_s": percentile(durs, 0.50),
            "p99_s": percentile(durs, 0.99),
        }
    return out
