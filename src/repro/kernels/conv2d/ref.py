"""XLA-conv oracle for the conv2d kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """SAME conv, stride 1, NHWC x HWIO -> NHWC."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)
