"""Direct-convolution Pallas kernel (paper §2.3/§4.3, 10-20x conv claim).

The 2017 OpenCL kernel tiles the output plane across work-groups; the TPU
re-derivation stages a whole (padded) input image in VMEM, tiles output
channels across the grid, and turns the KHxKW spatial taps into KH*KW
shifted (H*W, CI) x (CI, BCO) MXU matmuls accumulated in VMEM — an im2col
GEMM without materializing the im2col buffer in HBM.

Grid = (batch, out-channel blocks); weights are re-read per batch element,
input is re-read per channel block (both stream from HBM once per grid step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(
    x_ref,  # (1, H+KH-1, W+KW-1, CI) padded input
    w_ref,  # (KH, KW, CI, BCO)
    b_ref,  # (BCO,)
    o_ref,  # (1, H, W, BCO)
    *,
    H: int,
    W: int,
    KH: int,
    KW: int,
):
    CI = x_ref.shape[3]
    BCO = w_ref.shape[3]
    acc = jnp.zeros((H * W, BCO), jnp.float32)
    for kh in range(KH):
        for kw in range(KW):
            xs = x_ref[0, kh : kh + H, kw : kw + W, :].astype(jnp.float32)
            xs = xs.reshape(H * W, CI)
            wk = w_ref[kh, kw].astype(jnp.float32)  # (CI, BCO)
            acc = acc + jax.lax.dot(xs, wk, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[0] = acc.reshape(H, W, BCO).astype(o_ref.dtype)


def conv2d_fwd(
    x: jax.Array,  # (N, H, W, CI) — already SAME-padded by the wrapper
    w: jax.Array,  # (KH, KW, CI, CO)
    b: jax.Array,  # (CO,)
    *,
    out_h: int,
    out_w: int,
    block_co: int = 128,
    interpret: bool = True,
) -> jax.Array:
    N = x.shape[0]
    KH, KW, CI, CO = w.shape
    bco = min(block_co, CO)
    assert CO % bco == 0
    nco = CO // bco

    kernel = functools.partial(_conv_kernel, H=out_h, W=out_w, KH=KH, KW=KW)
    return pl.pallas_call(
        kernel,
        grid=(N, nco),
        in_specs=[
            pl.BlockSpec(
                (1, out_h + KH - 1, out_w + KW - 1, CI), lambda n, c: (n, 0, 0, 0)
            ),
            pl.BlockSpec((KH, KW, CI, bco), lambda n, c: (0, 0, 0, c)),
            pl.BlockSpec((bco,), lambda n, c: (c,)),
        ],
        out_specs=pl.BlockSpec((1, out_h, out_w, bco), lambda n, c: (n, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((N, out_h, out_w, CO), x.dtype),
        interpret=interpret,
    )(x, w, b)
