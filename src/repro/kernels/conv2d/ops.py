"""Jitted SAME-conv wrapper around the Pallas direct-conv kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.conv2d.kernel import conv2d_fwd


@functools.partial(jax.jit, static_argnames=("block_co", "interpret"))
def conv2d(
    x: jax.Array,  # (N, H, W, CI)
    w: jax.Array,  # (KH, KW, CI, CO)
    b: jax.Array | None = None,
    *,
    block_co: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """SAME convolution, stride 1 (odd kernel sizes)."""
    if interpret is None:
        interpret = default_interpret()
    N, H, W, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2 and KH % 2 == 1 and KW % 2 == 1
    if b is None:
        b = jnp.zeros((CO,), x.dtype)
    ph, pw = KH // 2, KW // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    return conv2d_fwd(
        xp, w, b, out_h=H, out_w=W, block_co=block_co, interpret=interpret
    )
