"""Shared kernel utilities."""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas kernels compile only on TPU; everywhere else run the kernel
    body in interpret mode (the brief's CPU-validation path)."""
    return jax.default_backend() != "tpu"


def tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    """Best-effort TPU compiler params (ignored in interpret mode)."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except Exception:
        return None
