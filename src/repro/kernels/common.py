"""Shared kernel utilities."""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas kernels compile only on TPU; everywhere else run the kernel
    body in interpret mode (the brief's CPU-validation path)."""
    return jax.default_backend() != "tpu"


def tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    """Best-effort TPU compiler params (ignored in interpret mode).

    The class was renamed across jax releases (``TPUCompilerParams`` →
    ``CompilerParams``); try both so the semantics actually reach the
    Mosaic compiler instead of silently degrading to ``None``."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        cls = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams", None
        )
        if cls is None:
            return None
        return cls(dimension_semantics=dimension_semantics)
    except Exception:
        return None
