"""Jitted public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, D) and handles layout, block-size
selection and the interpret/compiled switch.  Used by
``models.attention.attend`` when ``attention_impl='flash'``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, S, Hq, D) — model layout
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_fwd(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
    )
    return jnp.swapaxes(out, 1, 2)


def flash_attention_reference(q, k, v, *, causal=True):
    """(B,S,H,D)-layout oracle, for tests."""
    out = attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), causal=causal
    )
    return jnp.swapaxes(out, 1, 2)
