"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / (D ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vr.astype(jnp.float32)).astype(q.dtype)
