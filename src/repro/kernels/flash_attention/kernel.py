"""Flash attention (online softmax) Pallas kernel, GQA-aware.

Tiling: grid = (batch, q_heads, q_blocks, kv_blocks), kv innermost and
sequential.  Running row-max / row-sum / output accumulator live in VMEM
scratch and are rescaled per kv block (Dao et al., FlashAttention-2
schedule adapted to MXU tile shapes).  GQA never materializes repeated K/V:
the K/V BlockSpec index_map folds the query head onto its kv head
(``h // group``), so the same VMEM block serves the whole query group.

Causal masking is positional; fully-masked kv blocks are skipped via
``pl.when`` on the block index (upper-triangle blocks cost nothing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, d)
    o_ref,  # (1, 1, bq, d)
    m_scr,  # (bq,) fp32
    l_scr,  # (bq,) fp32
    acc_scr,  # (bq, d) fp32
    *,
    scale: float,
    causal: bool,
    bq: int,
    bk: int,
    nk: int,
):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip fully-masked (strictly upper-triangular) blocks
    run = (not causal) or (iq * bq + bq - 1 >= ik * bk)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, Hq, S, D)
    k: jax.Array,  # (B, Hkv, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    bq, bk = min(block_q, S), min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, bq=bq, bk=bk, nk=nk
    )
    kwargs = {}
    # batch / head / q-block axes are embarrassingly parallel; the kv axis
    # carries the online-softmax scratch and must stay sequential
    params = tpu_compiler_params(("parallel", "parallel", "parallel", "arbitrary"))
    if params is not None:
        kwargs["compiler_params"] = params
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            # running max / sum / output accumulator, persistent across the
            # sequential kv-block grid dimension
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
