"""Pure-jnp oracle for ICP correspondence + the rigid-alignment math."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def correspondences_ref(src: jax.Array, tgt: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Brute-force nearest neighbors. src (M,3), tgt (N,3) -> (idx, d2)."""
    d2 = jnp.sum(
        (src[:, None, :].astype(jnp.float32) - tgt[None, :, :].astype(jnp.float32)) ** 2,
        axis=-1,
    )
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def rigid_transform_ref(
    src: jax.Array, matched: jax.Array, weights: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Least-squares rigid transform (Horn/Umeyama): returns (R (3,3), t (3,))
    minimizing ||R src + t - matched||^2."""
    src = src.astype(jnp.float32)
    matched = matched.astype(jnp.float32)
    if weights is None:
        weights = jnp.ones((src.shape[0],), jnp.float32)
    w = weights / jnp.maximum(weights.sum(), 1e-9)
    cs = jnp.sum(src * w[:, None], axis=0)
    cm = jnp.sum(matched * w[:, None], axis=0)
    H = (src - cs).T @ ((matched - cm) * w[:, None])
    U, _, Vt = jnp.linalg.svd(H)
    det = jnp.linalg.det(Vt.T @ U.T)
    S = jnp.diag(jnp.array([1.0, 1.0, 1.0]) * jnp.where(
        jnp.arange(3) == 2, det, 1.0
    ))
    R = Vt.T @ S @ U.T
    t = cm - R @ cs
    return R, t
