"""ICP nearest-neighbor correspondence Pallas kernel (paper §5.2, 30x claim).

The GPU version parallelizes brute-force nearest-neighbor over CUDA threads.
TPU re-derivation: the pairwise distance matrix between a VMEM tile of source
points and a VMEM tile of target points is a *matmul* —
``‖s−t‖² = ‖s‖² + ‖t‖² − 2 s·tᵀ`` — so the MXU does the heavy lifting and a
running (argmin, min) pair per source point is kept in VMEM scratch across
the sequential target-tile grid dimension.

Coordinates are padded from 3 to a lane-friendly width by the ops wrapper
(zero padding leaves distances unchanged).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 3.4e38


def _icp_kernel(
    src_ref,  # (Bm, CD)
    tgt_ref,  # (Bn, CD)
    idx_ref,  # (Bm,) out int32
    d2_ref,  # (Bm,) out f32
    best_d_scr,  # (Bm,) f32
    best_i_scr,  # (Bm,) int32
    *,
    bn: int,
    n_blocks: int,
    n_valid: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_d_scr[...] = jnp.full_like(best_d_scr, BIG)
        best_i_scr[...] = jnp.zeros_like(best_i_scr)

    s = src_ref[...].astype(jnp.float32)  # (Bm, CD)
    t = tgt_ref[...].astype(jnp.float32)  # (Bn, CD)
    s2 = jnp.sum(s * s, axis=1, keepdims=True)  # (Bm, 1)
    t2 = jnp.sum(t * t, axis=1)  # (Bn,)
    cross = jax.lax.dot_general(
        s, t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Bm, Bn)
    d2 = s2 + t2[None, :] - 2.0 * cross
    # mask padded target rows (beyond n_valid)
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(col < n_valid, d2, BIG)

    cand_d = jnp.min(d2, axis=1)
    cand_i = (j * bn + jnp.argmin(d2, axis=1)).astype(jnp.int32)
    better = cand_d < best_d_scr[...]
    best_d_scr[...] = jnp.where(better, cand_d, best_d_scr[...])
    best_i_scr[...] = jnp.where(better, cand_i, best_i_scr[...])

    @pl.when(j == n_blocks - 1)
    def _final():
        idx_ref[...] = best_i_scr[...]
        d2_ref[...] = jnp.maximum(best_d_scr[...], 0.0)


def icp_correspondences_fwd(
    src: jax.Array,  # (M, CD) zero-padded coords
    tgt: jax.Array,  # (N, CD)
    *,
    n_valid_tgt: int,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    M, CD = src.shape
    N = tgt.shape[0]
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    nM, nN = M // bm, N // bn

    kernel = functools.partial(_icp_kernel, bn=bn, n_blocks=nN, n_valid=n_valid_tgt)
    idx, d2 = pl.pallas_call(
        kernel,
        grid=(nM, nN),
        in_specs=[
            pl.BlockSpec((bm, CD), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, CD), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M,), jnp.int32),
            jax.ShapeDtypeStruct((M,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm,), jnp.float32),
            pltpu.VMEM((bm,), jnp.int32),
        ],
        interpret=interpret,
    )(src, tgt)
    return idx, d2
