from repro.kernels.icp.ops import icp_correspondences, icp_step, icp_align  # noqa: F401
