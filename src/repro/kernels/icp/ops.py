"""Jitted ICP: Pallas correspondence kernel + closed-form rigid update.

``icp_align`` is the full point-cloud-alignment primitive the map-generation
pipeline calls (paper: "the most expensive operation for the map generation
stage is the iterative closest point alignment ... accelerated 30x on GPU").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.icp.kernel import icp_correspondences_fwd
from repro.kernels.icp.ref import rigid_transform_ref

COORD_PAD = 8  # pad xyz -> 8 lanes for the MXU distance matmul


def _pad_points(pts: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = pts.shape[0]
    m = ((n + multiple - 1) // multiple) * multiple
    padded = jnp.zeros((m, COORD_PAD), jnp.float32)
    padded = padded.at[:n, :3].set(pts.astype(jnp.float32))
    return padded, n


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def icp_correspondences(
    src: jax.Array,  # (M, 3)
    tgt: jax.Array,  # (N, 3)
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Nearest target index + squared distance for every source point."""
    if interpret is None:
        interpret = default_interpret()
    M = src.shape[0]
    srcp, _ = _pad_points(src, block_m)
    tgtp, n_tgt = _pad_points(tgt, block_n)
    idx, d2 = icp_correspondences_fwd(
        srcp, tgtp, n_valid_tgt=n_tgt, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    return idx[:M], d2[:M]


@functools.partial(jax.jit, static_argnames=("interpret",))
def icp_step(
    src: jax.Array,  # (M, 3) current source cloud
    tgt: jax.Array,  # (N, 3)
    *,
    trim_quantile: float = 0.9,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One ICP iteration: correspond -> trim outliers -> closed-form (R, t).

    Returns (R, t, mean_sq_err)."""
    idx, d2 = icp_correspondences(src, tgt, interpret=interpret)
    matched = tgt[idx]
    thresh = jnp.quantile(d2, trim_quantile)
    w = (d2 <= thresh).astype(jnp.float32)
    R, t = rigid_transform_ref(src, matched, w)
    err = jnp.sum(d2 * w) / jnp.maximum(w.sum(), 1.0)
    return R, t, err


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def icp_align(
    src: jax.Array,  # (M, 3)
    tgt: jax.Array,  # (N, 3)
    *,
    iters: int = 10,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full ICP: iterate correspond+solve. Returns (R, t, final mean_sq_err)
    with ``R src + t ~ tgt``."""

    def body(carry, _):
        R, t, _ = carry
        cur = src @ R.T + t
        dR, dt, err = icp_step(cur, tgt, interpret=interpret)
        return (dR @ R, dR @ t + dt, err), err

    init = (jnp.eye(3, dtype=jnp.float32), jnp.zeros((3,), jnp.float32), jnp.inf)
    (R, t, err), _ = jax.lax.scan(body, init, None, length=iters)
    return R, t, err
