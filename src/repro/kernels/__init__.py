"""Pallas TPU kernels for the platform's compute hot spots (paper §2.3).

The paper offloads hot kernels to accelerators via OpenCL (conv 10-20x,
ICP 30x).  Here each hot spot is a `pl.pallas_call` kernel with explicit
BlockSpec VMEM tiling, a jitted wrapper (ops.py) and a pure-jnp oracle
(ref.py).  Kernels run `interpret=True` on CPU (validation) and compiled on
TPU (the target).

  flash_attention/  -- online-softmax tiled attention (LM training hot spot)
  decode_attention/ -- paged GQA decode attention over block tables (serving)
  ssd/              -- Mamba-2 SSD chunk scan (SSM archs)
  icp/              -- ICP nearest-neighbor correspondence (HD map generation)
  conv2d/           -- im2col-MXU convolution (perception CNN / simulation)
"""
