"""Mamba-2 SSD chunk-scan Pallas kernel.

Grid = (batch, heads, chunks) with the chunk dimension sequential; the
(P, N) inter-chunk state lives in VMEM scratch and is carried across the
chunk grid steps — the whole recurrence never leaves VMEM.  Per chunk the
kernel computes, entirely in registers/VMEM:

  intra-chunk:  L = exp(segsum(dA));  Y_diag = (C B^T ⊙ L) @ (x·dt)
  state input:  Y_off  = (C @ state^T) ⊙ exp(cumsum dA)
  state update: state' = state·exp(Σ dA) + (x·dt)^T @ (B ⊙ decay_tail)

B/C group tensors are shared across the heads of a group via the BlockSpec
index_map (h -> h * G // H), mirroring the GQA trick in flash_attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    xdt_ref,  # (1, 1, 1, Q, P)
    dA_ref,  # (1, 1, 1, Q)
    B_ref,  # (1, 1, 1, Q, N)
    C_ref,  # (1, 1, 1, Q, N)
    y_ref,  # (1, 1, 1, Q, P) out
    st_ref,  # (1, 1, P, N) out (final state)
    state_scr,  # (P, N) fp32 scratch
    *,
    nc: int,
    Q: int,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    dA = dA_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    Bm = B_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)
    Cm = C_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)

    cs = jnp.cumsum(dA)  # (Q,)
    seg = cs[:, None] - cs[None, :]  # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (Q, Q), 1
    )
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    y_diag = jax.lax.dot((scores * L), xdt, preferred_element_type=jnp.float32)

    state = state_scr[...]
    y_off = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cs)[:, None]  # (Q, P)

    decay_tail = jnp.exp(cs[-1] - cs)  # (Q,)
    new_state = state * jnp.exp(cs[-1]) + jax.lax.dot_general(
        xdt, Bm * decay_tail[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    state_scr[...] = new_state

    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(c == nc - 1)
    def _final():
        st_ref[0, 0] = new_state.astype(st_ref.dtype)


def ssd_chunk_scan_fwd(
    xdt: jax.Array,  # (B, H, NC, Q, P) — x pre-multiplied by dt
    dA: jax.Array,  # (B, H, NC, Q)
    Bm: jax.Array,  # (B, G, NC, Q, N)
    Cm: jax.Array,  # (B, G, NC, Q, N)
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B_, H, NC, Q, P = xdt.shape
    G, N = Bm.shape[1], Bm.shape[4]
    assert H % G == 0

    kernel = functools.partial(_ssd_kernel, nc=NC, Q=Q)
    y, st = pl.pallas_call(
        kernel,
        grid=(B_, H, NC),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h * G // H, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h * G // H, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_, H, NC, Q, P), xdt.dtype),
            jax.ShapeDtypeStruct((B_, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bm, Cm)
    return y, st
