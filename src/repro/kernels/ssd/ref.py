"""Sequential-recurrence oracle for the SSD kernel (the literal SSM)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
) -> tuple[jax.Array, jax.Array]:
    """h_t = exp(dt A) h_{t-1} + dt x_t B_t^T;  y_t = h_t C_t.

    Returns (y (B,S,H,P), final state (B,H,P,N)).  fp32 throughout.
    """
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)

    def step(h, t):
        xt, dtt, bt, ct = x32[:, t], dt32[:, t], Bh[:, t], Ch[:, t]
        dA = jnp.exp(dtt * A)  # (B,H)
        h = h * dA[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT
