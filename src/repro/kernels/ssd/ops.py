"""Jitted wrapper: model-layout SSD via the Pallas chunk kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.ssd.kernel import ssd_chunk_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk_size", "interpret"))
def ssd_chunk_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — softplus'd
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    chunk_size: int = 64,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = default_interpret()
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk_size, S)
    assert S % Q == 0
    NC = S // Q

    xdt = (x.astype(jnp.float32) * dt[..., None].astype(jnp.float32))
    dA = dt.astype(jnp.float32) * A  # (B,S,H)

    # layouts: xdt (B,S,H,P) -> (B,H,NC,Q,P); dA (B,S,H) -> (B,H,NC,Q)
    xdt_c = jnp.transpose(xdt.reshape(B_, NC, Q, H, P), (0, 3, 1, 2, 4))
    dA_c = jnp.transpose(dA.reshape(B_, NC, Q, H), (0, 3, 1, 2))
    B_c = jnp.transpose(Bm.reshape(B_, NC, Q, G, N), (0, 3, 1, 2, 4)).astype(jnp.float32)
    C_c = jnp.transpose(Cm.reshape(B_, NC, Q, G, N), (0, 3, 1, 2, 4)).astype(jnp.float32)

    y, st = ssd_chunk_scan_fwd(xdt_c, dA_c, B_c, C_c, interpret=interpret)
    y = jnp.transpose(y, (0, 2, 3, 1, 4)).reshape(B_, S, H, P).astype(x.dtype)
    return y, st
