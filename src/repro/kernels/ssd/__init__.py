from repro.kernels.ssd.ops import ssd_chunk_scan  # noqa: F401
