"""Jitted wrapper over the collision/TTC Pallas kernel.

Pads the scenario axis to a sublane-friendly multiple and the agent axis to
a lane multiple, splits the vector inputs into the SoA component arrays the
kernel tiles over, and slices the pad back off.  Matches
:func:`repro.kernels.collision.ref.collision_ttc_ref` to fp32 tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.collision.kernel import collision_ttc_fwd
from repro.kernels.common import default_interpret


def _ceil_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block_s", "block_a", "interpret"))
def collision_ttc(
    ego_pos: jax.Array,  # (S, 2)
    ego_vel: jax.Array,  # (S, 2)
    ego_radius: jax.Array,  # (S,)
    agent_pos: jax.Array,  # (S, A, 2)
    agent_vel: jax.Array,  # (S, A, 2)
    agent_radius: jax.Array,  # (S, A)
    *,
    block_s: int = 256,
    block_a: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Signed distance, TTC and collision flag per ego-agent pair.

    Returns ``(dist (S,A) f32, ttc (S,A) f32, hit (S,A) bool)``."""
    if interpret is None:
        interpret = default_interpret()
    S, A = agent_radius.shape
    bs = min(block_s, _ceil_to(S, 8))
    ba = min(block_a, _ceil_to(A, 128))
    Sp, Ap = _ceil_to(S, bs), _ceil_to(A, ba)

    def pad_ego(x):
        return jnp.zeros((Sp,), jnp.float32).at[:S].set(x.astype(jnp.float32))

    def pad_agent(x):
        return jnp.zeros((Sp, Ap), jnp.float32).at[:S, :A].set(x.astype(jnp.float32))

    ego = (
        pad_ego(ego_pos[:, 0]), pad_ego(ego_pos[:, 1]),
        pad_ego(ego_vel[:, 0]), pad_ego(ego_vel[:, 1]),
        pad_ego(ego_radius),
    )
    agents = (
        pad_agent(agent_pos[..., 0]), pad_agent(agent_pos[..., 1]),
        pad_agent(agent_vel[..., 0]), pad_agent(agent_vel[..., 1]),
        pad_agent(agent_radius),
    )
    dist, ttc, hit = collision_ttc_fwd(
        ego, agents, n_valid_agents=A, block_s=bs, block_a=ba, interpret=interpret
    )
    return dist[:S, :A], ttc[:S, :A], hit[:S, :A].astype(bool)
