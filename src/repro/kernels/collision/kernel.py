"""Collision / time-to-collision Pallas kernel (paper §3 simulation service).

The closed-loop scenario simulator checks every ego-agent pair each world
step.  Over a fleet-scale batch that is a dense ``(S, A)`` problem: tiled
over scenarios (sublanes) x agents (lanes), the whole thing is elementwise
VPU math — signed disc distance plus the smaller positive root of the
constant-velocity quadratic ``|p + v t| = r_e + r_a``.

Ego state arrives as per-scenario 1-D blocks broadcast against the agent
tiles; both grid dimensions are embarrassingly parallel (no cross-tile
scratch).  Padded agent columns (beyond ``n_valid``) are masked to
``TTC_MAX`` / no-hit so the ops wrapper can pad freely to lane multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.collision.ref import TTC_MAX, _EPS
from repro.kernels.common import tpu_compiler_params


def _collision_kernel(
    ex_ref,  # (Bs,) ego x
    ey_ref,  # (Bs,)
    evx_ref,  # (Bs,) ego vel x
    evy_ref,  # (Bs,)
    er_ref,  # (Bs,) ego radius
    ax_ref,  # (Bs, Ba) agent x
    ay_ref,  # (Bs, Ba)
    avx_ref,  # (Bs, Ba)
    avy_ref,  # (Bs, Ba)
    ar_ref,  # (Bs, Ba) agent radius
    dist_ref,  # (Bs, Ba) out f32
    ttc_ref,  # (Bs, Ba) out f32
    hit_ref,  # (Bs, Ba) out int32
    *,
    ba: int,
    n_valid: int,
):
    j = pl.program_id(1)

    px = ax_ref[...] - ex_ref[...][:, None]
    py = ay_ref[...] - ey_ref[...][:, None]
    vx = avx_ref[...] - evx_ref[...][:, None]
    vy = avy_ref[...] - evy_ref[...][:, None]
    rad = ar_ref[...] + er_ref[...][:, None]

    c2 = px * px + py * py
    a = vx * vx + vy * vy
    b = 2.0 * (px * vx + py * vy)
    c = c2 - rad * rad

    dist = jnp.sqrt(jnp.maximum(c2, 0.0)) - rad
    disc = b * b - 4.0 * a * c
    t_hit = (-b - jnp.sqrt(jnp.maximum(disc, 0.0))) / (2.0 * a + _EPS)
    approaching = (disc > 0.0) & (t_hit > 0.0)
    ttc = jnp.where(c <= 0.0, 0.0, jnp.where(approaching, t_hit, TTC_MAX))
    hit = dist <= 0.0

    # mask padded agent columns
    col = j * ba + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    valid = col < n_valid
    dist_ref[...] = jnp.where(valid, dist, TTC_MAX)
    ttc_ref[...] = jnp.where(valid, ttc, TTC_MAX)
    hit_ref[...] = jnp.where(valid & hit, 1, 0).astype(jnp.int32)


def collision_ttc_fwd(
    ego_xyvr: tuple[jax.Array, ...],  # 5 x (S,) f32: x, y, vx, vy, r
    agent_xyvr: tuple[jax.Array, ...],  # 5 x (S, A) f32: x, y, vx, vy, r
    *,
    n_valid_agents: int,
    block_s: int = 256,
    block_a: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    S, A = agent_xyvr[0].shape
    bs, ba = min(block_s, S), min(block_a, A)
    assert S % bs == 0 and A % ba == 0, (S, A, bs, ba)
    nS, nA = S // bs, A // ba

    kernel = functools.partial(_collision_kernel, ba=ba, n_valid=n_valid_agents)
    kwargs = {}
    params = tpu_compiler_params(("parallel", "parallel"))
    if params is not None and not interpret:
        kwargs["compiler_params"] = params
    ego_spec = pl.BlockSpec((bs,), lambda i, j: (i,))
    agent_spec = pl.BlockSpec((bs, ba), lambda i, j: (i, j))
    dist, ttc, hit = pl.pallas_call(
        kernel,
        grid=(nS, nA),
        in_specs=[ego_spec] * 5 + [agent_spec] * 5,
        out_specs=[agent_spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((S, A), jnp.float32),
            jax.ShapeDtypeStruct((S, A), jnp.float32),
            jax.ShapeDtypeStruct((S, A), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(*ego_xyvr, *agent_xyvr)
    return dist, ttc, hit
