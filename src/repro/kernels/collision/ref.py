"""Pure-jnp oracle for the collision/TTC kernel.

Entities are discs: an ego disc per scenario and ``A`` agent discs.  For each
ego-agent pair the oracle returns

* ``dist`` — signed surface distance ``|p_a - p_e| - (r_e + r_a)`` (negative
  means overlap),
* ``ttc`` — time until the discs first touch under constant velocities,
  i.e. the smaller positive root of ``|p + v t| = r_e + r_a``;  ``0`` when
  already overlapping and ``TTC_MAX`` when the pair is not on a collision
  course,
* ``hit`` — boolean collision flag (``dist <= 0``).

The closed-loop world step evaluates exactly this math every tick; the
Pallas kernel in ``kernel.py`` is the tiled scenarios x agents version of it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TTC_MAX = 1e9
_EPS = 1e-9


def collision_ttc_ref(
    ego_pos: jax.Array,  # (S, 2)
    ego_vel: jax.Array,  # (S, 2)
    ego_radius: jax.Array,  # (S,)
    agent_pos: jax.Array,  # (S, A, 2)
    agent_vel: jax.Array,  # (S, A, 2)
    agent_radius: jax.Array,  # (S, A)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (dist (S,A) f32, ttc (S,A) f32, hit (S,A) bool)."""
    rel = agent_pos.astype(jnp.float32) - ego_pos.astype(jnp.float32)[:, None, :]
    rv = agent_vel.astype(jnp.float32) - ego_vel.astype(jnp.float32)[:, None, :]
    rad = ego_radius.astype(jnp.float32)[:, None] + agent_radius.astype(jnp.float32)

    # |rel|^2 and the quadratic |rel + rv t|^2 = rad^2:  a t^2 + b t + c = 0
    c2 = jnp.einsum("sad,sad->sa", rel, rel)
    a = jnp.einsum("sad,sad->sa", rv, rv)
    b = 2.0 * jnp.einsum("sad,sad->sa", rel, rv)
    c = c2 - rad * rad

    dist = jnp.sqrt(jnp.maximum(c2, 0.0)) - rad
    disc = b * b - 4.0 * a * c
    t_hit = (-b - jnp.sqrt(jnp.maximum(disc, 0.0))) / (2.0 * a + _EPS)
    approaching = (disc > 0.0) & (t_hit > 0.0)
    ttc = jnp.where(c <= 0.0, 0.0, jnp.where(approaching, t_hit, TTC_MAX))
    hit = dist <= 0.0
    return dist, ttc, hit
