"""Pairwise ego-agent collision / time-to-collision Pallas kernel package."""

from repro.kernels.collision.ops import collision_ttc  # noqa: F401
from repro.kernels.collision.ref import collision_ttc_ref  # noqa: F401
