"""Einsum oracle for the paged decode kernel.

Gathers the block-table pages back into a dense ``(B, T, Hkv, hd)`` cache
and runs the existing merged-softmax einsum decode path
(``attention.sdpa_decode_readonly``) over it.  This doubles as the
non-TPU runtime fallback: on backends where Pallas doesn't compile the
gather+einsum is the fastest correct path (ops.py routes here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_pages(
    pages: jax.Array,  # (P, page, Hkv, hd)
    block_tables: jax.Array,  # (B, n_pages) int32
) -> jax.Array:
    """Densify: (B, n_pages*page, Hkv, hd).  Null-page entries gather zeros
    past ``seq_len`` — masked out by the caller's positional mask."""
    B, n_pages = block_tables.shape
    page, Hkv, hd = pages.shape[1:]
    dense = jnp.take(pages, block_tables.reshape(-1), axis=0)
    return dense.reshape(B, n_pages * page, Hkv, hd)


def paged_decode_qtok_ref(
    q: jax.Array,  # (B, Q, Hq, hd) — Q-token window starting at seq_len
    k_pages: jax.Array,  # (P, page, Hkv, hd)
    v_pages: jax.Array,
    k_new: jax.Array,  # (B, Q, Hkv, hd) window tokens' K (not yet in pool)
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, n_pages)
    seq_lens: jax.Array,  # (B,)
    *,
    scores_dtype=jnp.float32,
) -> jax.Array:
    """Multi-query-token oracle: window token ``j`` sits at position
    ``seq_len + j`` and attends to every cached position (< seq_len) plus
    window tokens ``j' <= j`` (intra-window causal).  Serves speculative
    k-token verification and chunked prefill; ``Q == 1`` degenerates to
    ``paged_decode_ref``'s math."""
    B, Q, Hq, hd = q.shape
    ck = gather_pages(k_pages, block_tables)  # (B, S, Hkv, hd)
    cv = gather_pages(v_pages, block_tables)
    S, Hkv = ck.shape[1], ck.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Q, Hkv, G, hd).astype(scores_dtype)
    scale = jnp.asarray(1.0 / (hd ** 0.5), scores_dtype)
    neg = jnp.finfo(scores_dtype).min / 2

    sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck.astype(scores_dtype)) * scale
    cache_ok = jnp.arange(S, dtype=jnp.int32)[None, :] < seq_lens[:, None]
    sc = jnp.where(cache_ok[:, None, None, None, :], sc, neg)
    sn = jnp.einsum(
        "bqkgd,bukd->bkgqu", qg, k_new.astype(scores_dtype)
    ) * scale
    win_ok = (
        jnp.arange(Q, dtype=jnp.int32)[None, :]
        <= jnp.arange(Q, dtype=jnp.int32)[:, None]
    )
    sn = jnp.where(win_ok[None, None, None], sn, neg)

    s = jnp.concatenate([sc, sn], axis=-1)  # (B, Hkv, G, Q, S+Q)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / denom
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", p[..., :S], cv.astype(scores_dtype)
    ) + jnp.einsum(
        "bkgqu,bukd->bqkgd", p[..., S:], v_new.astype(scores_dtype)
    )
    return out.reshape(B, Q, Hq, hd).astype(q.dtype)


def paged_decode_ref(
    q: jax.Array,  # (B, 1, Hq, hd)
    k_pages: jax.Array,  # (P, page, Hkv, hd)
    v_pages: jax.Array,
    k_new: jax.Array,  # (B, 1, Hkv, hd)
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, n_pages)
    seq_lens: jax.Array,  # (B,)
    *,
    scores_dtype=jnp.float32,
) -> jax.Array:
    from repro.models.attention import sdpa_decode_readonly

    ck = gather_pages(k_pages, block_tables)
    cv = gather_pages(v_pages, block_tables)
    T = ck.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (q.shape[0], T))
    return sdpa_decode_readonly(
        q, ck, cv, k_new, v_new,
        q_pos=seq_lens[:, None].astype(jnp.int32),
        kv_pos=kv_pos,
        scores_dtype=scores_dtype,
    )
