"""Einsum oracle for the paged decode kernel.

Gathers the block-table pages back into a dense ``(B, T, Hkv, hd)`` cache
and runs the existing merged-softmax einsum decode path
(``attention.sdpa_decode_readonly``) over it.  This doubles as the
non-TPU runtime fallback: on backends where Pallas doesn't compile the
gather+einsum is the fastest correct path (ops.py routes here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_pages(
    pages: jax.Array,  # (P, page, Hkv, hd)
    block_tables: jax.Array,  # (B, n_pages) int32
) -> jax.Array:
    """Densify: (B, n_pages*page, Hkv, hd).  Null-page entries gather zeros
    past ``seq_len`` — masked out by the caller's positional mask."""
    B, n_pages = block_tables.shape
    page, Hkv, hd = pages.shape[1:]
    dense = jnp.take(pages, block_tables.reshape(-1), axis=0)
    return dense.reshape(B, n_pages * page, Hkv, hd)


def paged_decode_ref(
    q: jax.Array,  # (B, 1, Hq, hd)
    k_pages: jax.Array,  # (P, page, Hkv, hd)
    v_pages: jax.Array,
    k_new: jax.Array,  # (B, 1, Hkv, hd)
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, n_pages)
    seq_lens: jax.Array,  # (B,)
    *,
    scores_dtype=jnp.float32,
) -> jax.Array:
    from repro.models.attention import sdpa_decode_readonly

    ck = gather_pages(k_pages, block_tables)
    cv = gather_pages(v_pages, block_tables)
    T = ck.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (q.shape[0], T))
    return sdpa_decode_readonly(
        q, ck, cv, k_new, v_new,
        q_pos=seq_lens[:, None].astype(jnp.int32),
        kv_pos=kv_pos,
        scores_dtype=scores_dtype,
    )
