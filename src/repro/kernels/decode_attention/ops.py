"""Jitted public wrapper for paged GQA decode attention.

Accepts model-layout tensors (``q``/``k_new`` as ``(B, 1, H, hd)``) plus
the page pool and block tables, and routes:

* TPU — the Pallas kernel, compiled, gathering pages via scalar-prefetch
  block tables (``use_kernel=True`` forces the kernel elsewhere, in
  interpret mode — the tests' path).
* anywhere else — ``ref.paged_decode_ref``: a page gather + the existing
  ``sdpa_decode_readonly`` einsum path (interpret-mode Pallas is orders
  of magnitude slower than XLA on CPU, so the fallback is the *runtime*
  path there, not just the oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    paged_decode_fwd,
    paged_decode_qtok_fwd,
)
from repro.kernels.decode_attention.ref import (
    paged_decode_qtok_ref,
    paged_decode_ref,
)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_decode_attention(
    q: jax.Array,  # (B, Q, Hq, hd) — Q-token window starting at seq_len
    k_pages: jax.Array,  # (P, page, Hkv, hd) — pool; last page is the null page
    v_pages: jax.Array,
    k_new: jax.Array,  # (B, Q, Hkv, hd) window tokens (not yet in the pool)
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, n_pages) int32
    seq_lens: jax.Array,  # (B,) int32 live tokens strictly below the window
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, Q, Hq, hd) attention over [paged cache | causal window].

    ``Q == 1`` is classic decode (one current token merged analytically);
    ``Q > 1`` is the fast-path window — speculative verification and/or a
    chunked-prefill slab — where window token ``j`` attends the cache plus
    window tokens ``j' <= j``.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    Q = q.shape[1]
    if not use_kernel:
        if Q == 1:
            return paged_decode_ref(
                q, k_pages, v_pages, k_new, v_new, block_tables, seq_lens
            )
        return paged_decode_qtok_ref(
            q, k_pages, v_pages, k_new, v_new, block_tables, seq_lens
        )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    G = Hq // Hkv
    if Q == 1:
        qg = q.reshape(B, Hkv, G, hd)  # heads grouped under their kv head
        out = paged_decode_fwd(
            qg,
            k_pages,
            v_pages,
            k_new[:, 0],
            v_new[:, 0],
            block_tables.astype(jnp.int32),
            seq_lens.astype(jnp.int32),
            interpret=interpret,
        )
        return out.reshape(B, 1, Hq, hd)
    # window-major rows per kv head: (B, Hkv, Q*G, hd), row r = j*G + g
    qg = q.reshape(B, Q, Hkv, G, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(B, Hkv, Q * G, hd)
    out = paged_decode_qtok_fwd(
        qg,
        k_pages,
        v_pages,
        k_new,
        v_new,
        block_tables.astype(jnp.int32),
        seq_lens.astype(jnp.int32),
        group=G,
        interpret=interpret,
    )
    out = out.reshape(B, Hkv, Q, G, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Q, Hq, hd)
