"""Jitted public wrapper for paged GQA decode attention.

Accepts model-layout tensors (``q``/``k_new`` as ``(B, 1, H, hd)``) plus
the page pool and block tables, and routes:

* TPU — the Pallas kernel, compiled, gathering pages via scalar-prefetch
  block tables (``use_kernel=True`` forces the kernel elsewhere, in
  interpret mode — the tests' path).
* anywhere else — ``ref.paged_decode_ref``: a page gather + the existing
  ``sdpa_decode_readonly`` einsum path (interpret-mode Pallas is orders
  of magnitude slower than XLA on CPU, so the fallback is the *runtime*
  path there, not just the oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import paged_decode_fwd
from repro.kernels.decode_attention.ref import paged_decode_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_decode_attention(
    q: jax.Array,  # (B, 1, Hq, hd)
    k_pages: jax.Array,  # (P, page, Hkv, hd) — pool; last page is the null page
    v_pages: jax.Array,
    k_new: jax.Array,  # (B, 1, Hkv, hd) current token (not yet in the pool)
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, n_pages) int32
    seq_lens: jax.Array,  # (B,) int32 live tokens strictly below the query
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (B, 1, Hq, hd) attention over [paged cache | current token]."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return paged_decode_ref(
            q, k_pages, v_pages, k_new, v_new, block_tables, seq_lens
        )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, Hq, hd = q.shape
    Hkv = k_pages.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)  # heads grouped under their kv head
    out = paged_decode_fwd(
        qg,
        k_pages,
        v_pages,
        k_new[:, 0],
        v_new[:, 0],
        block_tables.astype(jnp.int32),
        seq_lens.astype(jnp.int32),
        interpret=interpret,
    )
    return out.reshape(B, 1, Hq, hd)
