"""Paged GQA decode attention (online softmax over block-table pages).

One query token per sequence attends over a KV cache stored as fixed-size
pages in a shared pool; the per-sequence page list (block table) and the
live length arrive as *scalar-prefetch* operands, so the K/V BlockSpec
index maps can gather pages straight from HBM — the kernel never sees a
dense ``(B, max_len)`` cache and HBM traffic scales with live tokens.

Tiling: grid = (batch, kv_heads, pages); the page axis is innermost and
sequential, with running max / sum / output accumulator in VMEM scratch
(FlashAttention-2 decode schedule).  GQA is native: the q block for kv
head ``h`` is that head's whole query group ``(G, hd)``, so pages are
fetched once per kv head, not per query head.

The current token's K/V are separate ``(B, Hkv, hd)`` operands merged
analytically at the final page step — mirroring
``attention.sdpa_decode_readonly``, the cache stays read-only and is
written once by the caller, outside the layer scan.

Pages past ``seq_len`` are skipped via ``pl.when`` (their block-table
entries point at the allocator's null page, so the prefetched index is
always in range); positions past ``seq_len`` inside the last live page
are masked positionally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import tpu_compiler_params

NEG_INF = -1e30


def _paged_decode_kernel(
    tables_ref,  # scalar prefetch: (B, n_pages) int32 page ids
    lens_ref,  # scalar prefetch: (B,) int32 live lengths (tokens < q_pos)
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, page, 1, hd) — page tables_ref[b, ip], kv head h
    v_ref,  # (1, page, 1, hd)
    kn_ref,  # (1, 1, hd) current token's key, kv head h
    vn_ref,  # (1, 1, hd)
    o_ref,  # (1, 1, G, hd)
    m_scr,  # (G,) fp32 running max
    l_scr,  # (G,) fp32 running sum
    acc_scr,  # (G, hd) fp32 output accumulator
    *,
    scale: float,
    page_size: int,
    n_pages: int,
):
    b, ip = pl.program_id(0), pl.program_id(2)
    seq_len = lens_ref[b]

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ip * page_size < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, page)
        pos = ip * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=1
        )
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finalize():
        # merge the current token (its cache slot is written after the layer
        # scan) — one extra online-softmax step with a single key
        q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
        kn = kn_ref[0, 0].astype(jnp.float32)  # (hd,)
        vn = vn_ref[0, 0].astype(jnp.float32)
        ln = (q @ kn) * scale  # (G,)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, ln)
        alpha = jnp.exp(m_prev - m_new)
        en = jnp.exp(ln - m_new)
        denom = l_scr[...] * alpha + en
        acc = acc_scr[...] * alpha[:, None] + en[:, None] * vn[None, :]
        o_ref[0, 0] = (acc / denom[:, None]).astype(o_ref.dtype)


def _paged_decode_qtok_kernel(
    tables_ref,  # scalar prefetch: (B, n_pages) int32 page ids
    lens_ref,  # scalar prefetch: (B,) int32 cached tokens (window starts here)
    q_ref,  # (1, 1, Q*G, hd) — window tokens × query group, row r = j*G + g
    k_ref,  # (1, page, 1, hd) — page tables_ref[b, ip], kv head h
    v_ref,  # (1, page, 1, hd)
    kn_ref,  # (1, Q, 1, hd) window tokens' keys, kv head h
    vn_ref,  # (1, Q, 1, hd)
    o_ref,  # (1, 1, Q*G, hd)
    m_scr,  # (Q*G,) fp32 running max
    l_scr,  # (Q*G,) fp32 running sum
    acc_scr,  # (Q*G, hd) fp32 output accumulator
    *,
    scale: float,
    page_size: int,
    n_pages: int,
    group: int,
):
    """Q-token window generalization of ``_paged_decode_kernel``: window
    token ``j`` sits at position ``seq_len + j``, so every window row sees
    the whole cache (pages phase is identical — the mask ``pos < seq_len``
    holds for all of them) and the finalize step merges the Q window keys
    under an intra-window causal mask (row ``j`` attends cols ``j' <= j``).
    Serves speculative k-token verification (Q = 1 + drafts) and chunked
    prefill (Q = chunk) with one schedule."""
    b, ip = pl.program_id(0), pl.program_id(2)
    seq_len = lens_ref[b]

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ip * page_size < seq_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (QG, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (QG, page)
        pos = ip * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=1
        )
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finalize():
        q = q_ref[0, 0].astype(jnp.float32)  # (QG, hd)
        kn = kn_ref[0, :, 0].astype(jnp.float32)  # (Q, hd)
        vn = vn_ref[0, :, 0].astype(jnp.float32)
        sn = jax.lax.dot_general(
            q, kn, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (QG, Q)
        row_tok = (
            jax.lax.broadcasted_iota(jnp.int32, sn.shape, dimension=0) // group
        )
        col_tok = jax.lax.broadcasted_iota(jnp.int32, sn.shape, dimension=1)
        sn = jnp.where(col_tok <= row_tok, sn, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sn, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        pn = jnp.exp(sn - m_new[:, None])
        denom = l_scr[...] * alpha + jnp.sum(pn, axis=1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            pn, vn, preferred_element_type=jnp.float32
        )
        o_ref[0, 0] = (acc / denom[:, None]).astype(o_ref.dtype)


def paged_decode_qtok_fwd(
    q: jax.Array,  # (B, Hkv, Q*G, hd) — window-major rows: r = j*G + g
    k_pages: jax.Array,  # (P, page, Hkv, hd) shared page pool (last page = null)
    v_pages: jax.Array,
    k_new: jax.Array,  # (B, Q, Hkv, hd) window tokens
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, n_pages) int32, null-page-padded
    seq_lens: jax.Array,  # (B,) int32 cached tokens (window begins here)
    *,
    group: int,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, QG, hd = q.shape
    Q = k_new.shape[1]
    assert QG == Q * group
    page_size = k_pages.shape[1]
    n_pages = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _paged_decode_qtok_kernel,
        scale=scale,
        page_size=page_size,
        n_pages=n_pages,
        group=group,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, QG, hd), lambda b, h, ip, tr, lr: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, page_size, 1, hd), lambda b, h, ip, tr, lr: (tr[b, ip], 0, h, 0)
            ),
            pl.BlockSpec(
                (1, page_size, 1, hd), lambda b, h, ip, tr, lr: (tr[b, ip], 0, h, 0)
            ),
            pl.BlockSpec((1, Q, 1, hd), lambda b, h, ip, tr, lr: (b, 0, h, 0)),
            pl.BlockSpec((1, Q, 1, hd), lambda b, h, ip, tr, lr: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, QG, hd), lambda b, h, ip, tr, lr: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((QG,), jnp.float32),
            pltpu.VMEM((QG,), jnp.float32),
            pltpu.VMEM((QG, hd), jnp.float32),
        ],
    )
    kwargs = {}
    params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    if params is not None:
        kwargs["compiler_params"] = params
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, QG, hd), q.dtype),
        interpret=interpret,
        **kwargs,
    )(block_tables, seq_lens, q, k_pages, v_pages, k_new, v_new)


def paged_decode_fwd(
    q: jax.Array,  # (B, Hkv, G, hd) — query heads grouped under their kv head
    k_pages: jax.Array,  # (P, page, Hkv, hd) shared page pool (last page = null)
    v_pages: jax.Array,
    k_new: jax.Array,  # (B, Hkv, hd) current token
    v_new: jax.Array,
    block_tables: jax.Array,  # (B, n_pages) int32, null-page-padded
    seq_lens: jax.Array,  # (B,) int32 tokens already in cache (< q_pos)
    *,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, hd = q.shape
    page_size = k_pages.shape[1]
    n_pages = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, page_size=page_size, n_pages=n_pages
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ip, tr, lr: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, page_size, 1, hd), lambda b, h, ip, tr, lr: (tr[b, ip], 0, h, 0)
            ),
            pl.BlockSpec(
                (1, page_size, 1, hd), lambda b, h, ip, tr, lr: (tr[b, ip], 0, h, 0)
            ),
            pl.BlockSpec((1, 1, hd), lambda b, h, ip, tr, lr: (b, h, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, h, ip, tr, lr: (b, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ip, tr, lr: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kwargs = {}
    params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    if params is not None:
        kwargs["compiler_params"] = params
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
        **kwargs,
    )(block_tables, seq_lens, q, k_pages, v_pages, k_new, v_new)
