from repro.kernels.decode_attention.ops import paged_decode_attention  # noqa: F401
