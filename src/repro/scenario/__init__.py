"""Closed-loop scenario simulation service (paper §3).

Where ``repro.sim`` replays recorded logs open-loop, this subsystem *drives*:
a candidate planner closes the loop against scripted/reactive traffic over
thousands of scenarios stepped as one batched SoA program.

* :mod:`repro.scenario.world` — jitted batched world step (ego bicycle model
  + phase-scripted agents) rolled out with ``lax.scan`` and donated state;
* :mod:`repro.scenario.dsl` — declarative scenario specs, a library of
  scenario families, and PRNG-split randomized parameter sweeps compiled to
  initial-state tensors;
* :mod:`repro.scenario.metrics` — safety-metric aggregation into a
  :class:`ScenarioReport` (collision rate, min-TTC histogram, violations);
* :mod:`repro.scenario.runner` — fleet runner sharding scenario batches over
  ``core.scheduler`` containers plus the A/B planner qualification gate.
"""

from repro.scenario.dsl import (  # noqa: F401
    FAMILIES,
    AgentSpec,
    ScenarioSpec,
    build_batch,
    compile_specs,
)
from repro.scenario.metrics import ScenarioReport, aggregate, qualify  # noqa: F401
from repro.scenario.runner import FleetRunner  # noqa: F401
from repro.scenario.world import aeb_policy, baseline_policy, rollout  # noqa: F401
