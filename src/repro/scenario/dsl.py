"""Declarative scenario DSL + family library (paper §3: "as many scenarios
as you can imagine").

A :class:`ScenarioSpec` is a plain declarative description — ego initial
state plus a tuple of :class:`AgentSpec` three-phase scripts.  Family
builders (``cut_in``, ``hard_brake_lead``, ``merge``,
``pedestrian_crossing``, ``occluded_intersection``) sample spec parameters
from documented ranges via PRNG-split perturbations, so a single seed fans
out into a randomized sweep; deterministic ``*_spec`` constructors expose
the canonical instance of each family for tests.

:func:`compile_specs` lowers a list of specs into the SoA
:class:`~repro.scenario.world.ScenarioBatch` tensors the jitted world step
consumes (agent axis padded to the widest spec, invalid slots parked far
away with zero radius).

Geometry conventions: ego starts at the origin heading +x, lane centers at
``y = 0, ±3.5``; distances in meters, speeds m/s, times seconds.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.scenario.world import ScenarioBatch

LANE_W = 3.5
FAR = 1.0e6  # parking spot for padded agent slots
NEVER = 1.0e9  # phase switch time that never arrives


@dataclasses.dataclass(frozen=True)
class AgentSpec:
    """One scripted traffic participant (vehicle or pedestrian)."""

    x: float
    y: float
    psi: float = 0.0
    v: float = 0.0
    radius: float = 2.0
    accel_phases: tuple[float, float, float] = (0.0, 0.0, 0.0)
    yaw_phases: tuple[float, float, float] = (0.0, 0.0, 0.0)
    phase_times: tuple[float, float] = (NEVER, NEVER)
    reactive: bool = False


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One closed-loop scenario: ego initial condition + scripted agents."""

    family: str
    ego_v: float
    ego_target_v: float | None = None  # defaults to ego_v
    ego_y: float = 0.0
    ego_psi: float = 0.0
    ego_radius: float = 2.0
    speed_limit: float = 30.0
    agents: tuple[AgentSpec, ...] = ()


# ---------------------------------------------------------------------------
# Spec -> tensor compilation
# ---------------------------------------------------------------------------


def compile_specs(specs: Sequence[ScenarioSpec]) -> tuple[ScenarioBatch, list[str]]:
    """Lower specs into ``(ScenarioBatch, family_names)``; ``family_id``
    indexes ``family_names`` (stable first-appearance order)."""
    if not specs:
        raise ValueError("compile_specs: empty spec list")
    S = len(specs)
    A = max(1, max(len(s.agents) for s in specs))
    names = list(dict.fromkeys(s.family for s in specs))
    fid = {n: i for i, n in enumerate(names)}

    ego = np.zeros((S, 7), np.float32)  # x0 y0 psi0 v0 radius target_v limit
    family = np.zeros((S,), np.int32)
    agf = {
        k: np.zeros((S, A), np.float32)
        for k in ("x", "y", "psi", "v", "radius", "reactive", "valid")
    }
    agf["x"].fill(FAR)
    agf["y"].fill(FAR)
    accel = np.zeros((S, A, 3), np.float32)
    yaw = np.zeros((S, A, 3), np.float32)
    times = np.full((S, A, 2), NEVER, np.float32)

    for i, s in enumerate(specs):
        tv = s.ego_v if s.ego_target_v is None else s.ego_target_v
        ego[i] = (0.0, s.ego_y, s.ego_psi, s.ego_v, s.ego_radius, tv, s.speed_limit)
        family[i] = fid[s.family]
        for j, a in enumerate(s.agents):
            agf["x"][i, j] = a.x
            agf["y"][i, j] = a.y
            agf["psi"][i, j] = a.psi
            agf["v"][i, j] = a.v
            agf["radius"][i, j] = a.radius
            agf["reactive"][i, j] = float(a.reactive)
            agf["valid"][i, j] = 1.0
            accel[i, j] = a.accel_phases
            yaw[i, j] = a.yaw_phases
            times[i, j] = a.phase_times

    batch = ScenarioBatch(
        ego_x0=jnp.asarray(ego[:, 0]),
        ego_y0=jnp.asarray(ego[:, 1]),
        ego_psi0=jnp.asarray(ego[:, 2]),
        ego_v0=jnp.asarray(ego[:, 3]),
        ego_radius=jnp.asarray(ego[:, 4]),
        target_v=jnp.asarray(ego[:, 5]),
        speed_limit=jnp.asarray(ego[:, 6]),
        family_id=jnp.asarray(family),
        ag_x0=jnp.asarray(agf["x"]),
        ag_y0=jnp.asarray(agf["y"]),
        ag_psi0=jnp.asarray(agf["psi"]),
        ag_v0=jnp.asarray(agf["v"]),
        ag_radius=jnp.asarray(agf["radius"]),
        accel_phases=jnp.asarray(accel),
        yaw_phases=jnp.asarray(yaw),
        phase_t=jnp.asarray(times),
        reactive=jnp.asarray(agf["reactive"]),
        valid=jnp.asarray(agf["valid"]),
    )
    return batch, names


# ---------------------------------------------------------------------------
# Canonical (deterministic) family instances
# ---------------------------------------------------------------------------


def hard_brake_spec(
    gap: float = 18.0, v: float = 15.0, brake_t: float = 1.0, decel: float = 7.0
) -> ScenarioSpec:
    """Lead vehicle ahead slams the brakes at ``brake_t``."""
    lead = AgentSpec(
        x=gap, y=0.0, v=v,
        accel_phases=(0.0, -decel, -decel), phase_times=(brake_t, NEVER),
    )
    return ScenarioSpec(family="hard_brake_lead", ego_v=v, agents=(lead,))


def cut_in_spec(
    dx: float = 8.0, dv: float = 2.5, ego_v: float = 15.0,
    yaw_rate: float = 0.08, turn_s: float = 1.7,
) -> ScenarioSpec:
    """Slower adjacent-lane vehicle swerves into the ego lane ``dv`` m/s
    under ego speed, then straightens — the ego closes in from behind."""
    cutter = AgentSpec(
        x=dx, y=LANE_W, v=max(ego_v - dv, 0.0),
        yaw_phases=(-yaw_rate, yaw_rate, 0.0), phase_times=(turn_s, 2 * turn_s),
    )
    return ScenarioSpec(family="cut_in", ego_v=ego_v, agents=(cutter,))


def merge_spec(
    dx: float = 0.0, ego_v: float = 14.0, ramp_v: float = 11.0,
    yaw_rate: float = 0.08, turn_s: float = 1.7, accel: float = 1.2,
) -> ScenarioSpec:
    """On-ramp vehicle accelerates and merges up into the ego lane."""
    merger = AgentSpec(
        x=dx, y=-LANE_W, v=ramp_v, reactive=True,
        accel_phases=(accel, accel, 0.0),
        yaw_phases=(yaw_rate, -yaw_rate, 0.0), phase_times=(turn_s, 2 * turn_s),
    )
    return ScenarioSpec(family="merge", ego_v=ego_v, agents=(merger,))


def pedestrian_spec(
    dx: float = 28.0, start_t: float = 0.8, walk_v: float = 1.4, ego_v: float = 12.0
) -> ScenarioSpec:
    """Pedestrian at the curb starts crossing after ``start_t`` seconds;
    reactive (pauses rather than walking into a vehicle blocking the path)."""
    ped = AgentSpec(
        x=dx, y=-6.0, psi=math.pi / 2, v=0.0, radius=0.4, reactive=True,
        accel_phases=(0.0, walk_v, 0.0), phase_times=(start_t, start_t + 1.0),
    )
    return ScenarioSpec(family="pedestrian_crossing", ego_v=ego_v, agents=(ped,))


def intersection_spec(
    dx: float = 30.0, cross_v: float = 9.0, ego_v: float = 13.0
) -> ScenarioSpec:
    """Cross traffic from the right, sightline blocked by a parked truck."""
    crosser = AgentSpec(x=dx, y=-18.0, psi=math.pi / 2, v=cross_v)
    occluder = AgentSpec(x=dx - 8.0, y=-4.5, v=0.0, radius=2.2)
    return ScenarioSpec(family="occluded_intersection", ego_v=ego_v,
                        agents=(crosser, occluder))


# ---------------------------------------------------------------------------
# Randomized family sweeps (PRNG-split perturbations)
# ---------------------------------------------------------------------------


def _sweep(key: jax.Array, n: int, ranges: Sequence[tuple[float, float]]) -> np.ndarray:
    """(n, len(ranges)) uniform samples, one column per parameter range."""
    u = np.asarray(jax.random.uniform(key, (n, len(ranges)), jnp.float32))
    lo = np.array([r[0] for r in ranges], np.float32)
    hi = np.array([r[1] for r in ranges], np.float32)
    return lo + (hi - lo) * u


def hard_brake_lead(key: jax.Array, n: int = 1) -> list[ScenarioSpec]:
    p = _sweep(key, n, [(15.0, 25.0), (12.0, 18.0), (0.6, 1.4), (6.0, 8.0)])
    return [hard_brake_spec(*row) for row in p]


def cut_in(key: jax.Array, n: int = 1) -> list[ScenarioSpec]:
    p = _sweep(key, n, [(6.0, 12.0), (1.0, 4.0), (12.0, 18.0), (0.06, 0.1), (1.4, 2.0)])
    return [cut_in_spec(*row) for row in p]


def merge(key: jax.Array, n: int = 1) -> list[ScenarioSpec]:
    p = _sweep(key, n, [(-5.0, 5.0), (12.0, 16.0), (9.0, 13.0)])
    return [merge_spec(*row) for row in p]


def pedestrian_crossing(key: jax.Array, n: int = 1) -> list[ScenarioSpec]:
    p = _sweep(key, n, [(20.0, 40.0), (0.3, 1.5), (1.1, 1.8), (10.0, 15.0)])
    return [pedestrian_spec(*row) for row in p]


def occluded_intersection(key: jax.Array, n: int = 1) -> list[ScenarioSpec]:
    p = _sweep(key, n, [(25.0, 40.0), (7.0, 12.0), (11.0, 15.0)])
    return [intersection_spec(*row) for row in p]


FAMILIES: dict[str, Callable[[jax.Array, int], list[ScenarioSpec]]] = {
    "hard_brake_lead": hard_brake_lead,
    "cut_in": cut_in,
    "merge": merge,
    "pedestrian_crossing": pedestrian_crossing,
    "occluded_intersection": occluded_intersection,
}


def build_batch(
    families: Sequence[str] | None = None,
    per_family: int = 32,
    key: jax.Array | None = None,
) -> tuple[ScenarioBatch, list[str]]:
    """Fan the given families (default: all five) into a compiled randomized
    sweep of ``per_family`` scenarios each — one PRNG split per family, so
    the batch is a pure function of the seed."""
    families = list(FAMILIES) if families is None else list(families)
    key = jax.random.PRNGKey(0) if key is None else key
    specs: list[ScenarioSpec] = []
    for fam, k in zip(families, jax.random.split(key, len(families))):
        specs.extend(FAMILIES[fam](k, per_family))
    return compile_specs(specs)
