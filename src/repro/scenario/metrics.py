"""Safety-metric aggregation for scenario sweeps (paper §3 qualification).

Per-scenario rollout outputs (collision flag, min signed distance, min TTC,
rule-violation counts) aggregate into a :class:`ScenarioReport` with
per-family breakdowns, and :func:`qualify` is the A/B planner qualification
gate — the closed-loop analog of ``ReplaySimulator.ab_test``'s "quick
verification before on-road testing".
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

DEFAULT_TTC_BINS = (0.0, 0.5, 1.0, 2.0, 3.0, 5.0)


@dataclasses.dataclass
class FamilyStats:
    scenarios: int
    collisions: int
    collision_rate: float
    mean_min_dist: float
    min_ttc_hist: list[int]  # counts per DEFAULT_TTC_BINS bucket (last = >= last edge)
    violation_rate: float  # fraction of scenarios with >= 1 speeding step


@dataclasses.dataclass
class ScenarioReport:
    scenarios: int
    steps: int
    wall_time_s: float
    scenarios_per_sec: float
    steps_per_sec: float  # scenario-steps / s (the fleet throughput figure)
    collision_rate: float
    families: dict[str, FamilyStats]
    ttc_bin_edges: tuple[float, ...] = DEFAULT_TTC_BINS

    def summary(self) -> str:
        lines = [
            f"scenarios={self.scenarios} steps={self.steps} "
            f"wall={self.wall_time_s:.2f}s "
            f"({self.scenarios_per_sec:.0f} scen/s, {self.steps_per_sec:.0f} scen-steps/s) "
            f"collision_rate={self.collision_rate:.3f}"
        ]
        for name, fs in sorted(self.families.items()):
            lines.append(
                f"  {name:24s} n={fs.scenarios:4d} collisions={fs.collisions:3d} "
                f"({fs.collision_rate:.3f}) min_dist={fs.mean_min_dist:6.2f}m "
                f"ttc_hist={fs.min_ttc_hist} viol={fs.violation_rate:.3f}"
            )
        return "\n".join(lines)


def _ttc_hist(ttc: np.ndarray, edges: tuple[float, ...]) -> list[int]:
    bins = list(edges) + [np.inf]
    hist, _ = np.histogram(ttc, bins=bins)
    return hist.astype(int).tolist()


def aggregate(
    family_ids: np.ndarray,
    family_names: list[str],
    collided: np.ndarray,
    min_ttc: np.ndarray,
    min_dist: np.ndarray,
    violations: np.ndarray,
    *,
    steps: int,
    wall_time_s: float,
    ttc_bins: tuple[float, ...] = DEFAULT_TTC_BINS,
) -> ScenarioReport:
    family_ids = np.asarray(family_ids)
    collided = np.asarray(collided).astype(bool)
    min_ttc = np.asarray(min_ttc, np.float64)
    min_dist = np.asarray(min_dist, np.float64)
    violations = np.asarray(violations)
    S = collided.shape[0]

    families: dict[str, FamilyStats] = {}
    for i, name in enumerate(family_names):
        m = family_ids == i
        n = int(m.sum())
        if n == 0:
            continue
        families[name] = FamilyStats(
            scenarios=n,
            collisions=int(collided[m].sum()),
            collision_rate=float(collided[m].mean()),
            mean_min_dist=float(min_dist[m].mean()),
            min_ttc_hist=_ttc_hist(min_ttc[m], ttc_bins),
            violation_rate=float((violations[m] > 0).mean()),
        )
    wall = max(wall_time_s, 1e-9)
    return ScenarioReport(
        scenarios=S,
        steps=steps,
        wall_time_s=wall_time_s,
        scenarios_per_sec=S / wall,
        steps_per_sec=S * steps / wall,
        collision_rate=float(collided.mean()) if S else 0.0,
        families=families,
        ttc_bin_edges=ttc_bins,
    )


# ---------------------------------------------------------------------------
# rollout <-> record round-trip (campaign artifact payloads)
# ---------------------------------------------------------------------------


def rollout_record(family_ids, family_names, rollout, *, steps: int) -> dict:
    """Flatten a sweep's raw rollout outputs into a flat record the BinPipe
    codec can encode (str/int/ndarray values only).  Deliberately carries
    **no timing fields**, so the record's content hash — and therefore a
    campaign artifact version built from it — is identical across runs that
    differ only in wall clock (the bitwise chaos-equality story)."""
    rec: dict = {
        "family_ids": np.asarray(family_ids),
        "family_names": json.dumps(list(family_names)),
        "steps": int(steps),
    }
    for f in ("collided", "min_ttc", "min_dist", "violations"):
        a = np.asarray(getattr(rollout, f))
        # BinPipe round-trips raw dtypes; normalize only bool (flag) arrays
        rec[f] = a.astype(np.uint8) if a.dtype == np.bool_ else a
    return rec


def report_from_record(rec: dict, *, wall_time_s: float = 1.0) -> ScenarioReport:
    """Rebuild a :class:`ScenarioReport` from a :func:`rollout_record`.
    ``wall_time_s`` defaults to a fixed 1.0 so the derived throughput fields
    are deterministic — the record intentionally has no timing of its own."""
    return aggregate(
        np.asarray(rec["family_ids"]),
        list(json.loads(rec["family_names"])),
        np.asarray(rec["collided"]).astype(bool),
        np.asarray(rec["min_ttc"]),
        np.asarray(rec["min_dist"]),
        np.asarray(rec["violations"]),
        steps=int(rec["steps"]),
        wall_time_s=wall_time_s,
    )


# ---------------------------------------------------------------------------
# A/B planner qualification gate
# ---------------------------------------------------------------------------


def merge_rollouts(
    family_ids,
    family_names: list[str],
    chunks,
    *,
    steps: int,
    wall_time_s: float,
) -> ScenarioReport:
    """Concatenate per-shard :class:`~repro.scenario.world.RolloutMetrics`
    (in shard order, matching the concatenation of ``family_ids``) and
    aggregate into one report — shared by ``FleetRunner`` and the platform's
    scenario driver so sweep aggregation has a single implementation."""
    cat = lambda f: np.concatenate([np.asarray(getattr(m, f)) for m in chunks])
    return aggregate(
        np.concatenate([np.asarray(ids) for ids in family_ids]),
        list(family_names),
        cat("collided"),
        cat("min_ttc"),
        cat("min_dist"),
        cat("violations"),
        steps=steps,
        wall_time_s=wall_time_s,
    )


@dataclasses.dataclass
class QualificationResult:
    passed: bool
    baseline_collision_rate: float
    candidate_collision_rate: float
    reasons: list[str]

    def verdict(self) -> str:
        return "QUALIFY for road test" if self.passed else "REJECT: " + "; ".join(self.reasons)


def qualify(
    baseline: ScenarioReport,
    candidate: ScenarioReport,
    *,
    max_collision_regression: float = 0.0,
    max_family_regression: float = 0.02,
) -> QualificationResult:
    """Gate a candidate planner against the deployed baseline: overall
    collision rate must not regress beyond ``max_collision_regression``, nor
    any shared scenario family beyond ``max_family_regression``."""
    reasons = []
    if candidate.collision_rate > baseline.collision_rate + max_collision_regression:
        reasons.append(
            f"overall collision rate {candidate.collision_rate:.3f} > "
            f"baseline {baseline.collision_rate:.3f} + {max_collision_regression}"
        )
    for name, b in baseline.families.items():
        c = candidate.families.get(name)
        if c is not None and c.collision_rate > b.collision_rate + max_family_regression:
            reasons.append(
                f"family {name}: {c.collision_rate:.3f} > "
                f"{b.collision_rate:.3f} + {max_family_regression}"
            )
    return QualificationResult(
        passed=not reasons,
        baseline_collision_rate=baseline.collision_rate,
        candidate_collision_rate=candidate.collision_rate,
        reasons=reasons,
    )
