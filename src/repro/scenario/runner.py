"""Fleet-scale scenario sweep runner (paper §3 distributed simulation).

Shards a compiled :class:`~repro.scenario.world.ScenarioBatch` across
``core.scheduler.ResourceManager`` containers (job kind ``simulate`` — the
YARN-queue analog), closes the loop on every shard, and aggregates
per-scenario safety metrics into a :class:`~repro.scenario.metrics.ScenarioReport`.

Like ``ReplaySimulator``, shard execution is in-process (the single-host
stand-in for the cluster executors); the scheduler still does real
admission/queueing work, so sweeps coexist with train/serve jobs on the
shared device pool — shards queue while the pool is busy and run as
containers free up.  ``ab_test`` is the closed-loop planner qualification
flow: same scenario sweep, deployed vs candidate policy, gated by
:func:`~repro.scenario.metrics.qualify`.
"""

from __future__ import annotations

import time
from typing import Sequence

import jax
import numpy as np

from repro.core.scheduler import JOB_DONE, JOB_RUNNING, Job, ResourceManager
from repro.scenario import metrics as M
from repro.scenario.world import Policy, RolloutMetrics, ScenarioBatch, rollout


def slice_batch(batch: ScenarioBatch, lo: int, hi: int) -> ScenarioBatch:
    """A contiguous shard of a compiled scenario batch (every field sliced)."""
    return jax.tree_util.tree_map(lambda x: x[lo:hi], batch)


class FleetRunner:
    """Runs scenario sweeps as ``simulate`` jobs on a shared device pool."""

    def __init__(
        self,
        rm: ResourceManager,
        *,
        shards: int = 4,
        devices_per_shard: int = 1,
        steps: int = 100,
        dt: float = 0.1,
        use_pallas: bool = False,
        priority: int = 0,
        schedule_timeout_s: float = 60.0,
    ):
        self.rm = rm
        self.shards = shards
        self.devices_per_shard = devices_per_shard
        self.steps = steps
        self.dt = dt
        self.use_pallas = use_pallas
        self.priority = priority
        self.schedule_timeout_s = schedule_timeout_s
        self.shard_times_s: list[float] = []

    # ------------------------------------------------------------------
    def _run_shard(self, shard: ScenarioBatch, policy: Policy) -> RolloutMetrics:
        m, _ = rollout(
            shard, policy, steps=self.steps, dt=self.dt, use_pallas=self.use_pallas
        )
        return jax.block_until_ready(m)

    def run(
        self,
        batch: ScenarioBatch,
        family_names: Sequence[str],
        policy: Policy,
        *,
        job_prefix: str = "scenario",
    ) -> M.ScenarioReport:
        """Shard the batch, schedule one ``simulate`` job per shard, execute
        scheduled shards as their containers come up, aggregate."""
        S = batch.num_scenarios
        n_shards = max(1, min(self.shards, S))
        bounds = np.linspace(0, S, n_shards + 1, dtype=int)
        names = [f"{job_prefix}-{time.monotonic_ns()}-{i}" for i in range(n_shards)]

        t0 = time.perf_counter()
        for name in names:
            self.rm.submit(Job(
                name, "simulate", devices=self.devices_per_shard,
                min_devices=1, priority=self.priority,
            ))

        done: dict[int, RolloutMetrics] = {}
        self.shard_times_s = [0.0] * n_shards
        try:
            self._drain(batch, policy, names, bounds, done, t0)
        finally:
            # never leak queued/assigned shard jobs into the shared pool,
            # even when aborting on timeout or a shard failure
            for name in names:
                if self.rm.jobs[name].state != JOB_DONE:
                    self.rm.complete(name)
        wall = time.perf_counter() - t0

        return M.merge_rollouts(
            [batch.family_id],
            list(family_names),
            [done[i] for i in range(n_shards)],
            steps=self.steps,
            wall_time_s=wall,
        )

    def _drain(
        self,
        batch: ScenarioBatch,
        policy: Policy,
        names: list[str],
        bounds: np.ndarray,
        done: dict[int, RolloutMetrics],
        t0: float,
    ) -> None:
        n_shards = len(names)
        while len(done) < n_shards:
            ran_any = False
            for i, name in enumerate(names):
                job = self.rm.jobs[name]
                if i in done or job.state != JOB_RUNNING:
                    continue
                ts = time.perf_counter()
                done[i] = self._run_shard(
                    slice_batch(batch, int(bounds[i]), int(bounds[i + 1])), policy
                )
                self.shard_times_s[i] = time.perf_counter() - ts
                self.rm.complete(name)  # frees the container, reschedules queue
                ran_any = True
            if not ran_any:
                # pool held by foreign train/serve jobs: wait for their
                # containers to free up (another thread drives rm.complete)
                foreign = self.rm.running_jobs(exclude=names)
                if foreign and time.perf_counter() - t0 < self.schedule_timeout_s:
                    # the completing thread's rm.complete() reschedules the
                    # queue; just poll job states here
                    time.sleep(0.01)
                    continue
                stuck = [names[i] for i in range(n_shards) if i not in done]
                raise RuntimeError(
                    f"scenario shards cannot be scheduled: {stuck}"
                    + (f" (pool held by {foreign})" if foreign else "")
                )

    # ------------------------------------------------------------------
    def ab_test(
        self,
        batch: ScenarioBatch,
        family_names: Sequence[str],
        deployed: Policy,
        candidate: Policy,
        **gate_kwargs,
    ) -> tuple[M.ScenarioReport, M.ScenarioReport, M.QualificationResult]:
        """Closed-loop qualification: same sweep under both planners, gated
        on collision-rate regression (overall and per family)."""
        rep_a = self.run(batch, family_names, deployed, job_prefix="ab-deployed")
        rep_b = self.run(batch, family_names, candidate, job_prefix="ab-candidate")
        return rep_a, rep_b, M.qualify(rep_a, rep_b, **gate_kwargs)
