"""Vectorized closed-loop world step (paper §3 simulation service).

Thousands of scenarios advance together as one SoA program: ego state is
``(S,)`` per component, agent state ``(S, A)``, and one jitted
``lax.scan`` over time steps the whole fleet batch.  The carry (the world
state) is donated, so the rollout runs in-place buffer-wise.

* **Ego** follows a kinematic bicycle model driven by a *policy* — the
  algorithm under test.  A policy is a jittable
  ``obs -> (accel (S,), steer (S,))`` function; two built-ins are provided
  (:func:`baseline_policy` lane-keep cruise, :func:`aeb_policy` the same
  plus autonomous emergency braking on TTC/gap).
* **Agents** are scripted by three-phase (accel, yaw-rate) profiles with two
  switch times — enough to express cut-ins, hard brakes, merges, crossing
  pedestrians and cross traffic — plus an optional *reactive* flag that
  makes an agent brake when the ego is close ahead of it.
* **Safety signals** (signed distance, TTC, collision flags) are the
  collision-kernel math from :mod:`repro.kernels.collision`; set
  ``use_pallas=True`` to route them through the Pallas kernel (the TPU
  path), default is the fused jnp oracle.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.collision.ref import TTC_MAX, collision_ttc_ref

WHEELBASE = 2.8  # m, ego kinematic bicycle
V_MAX = 60.0  # m/s hard clamp
REACT_DIST = 15.0  # m, reactive agents brake when ego is closer ahead
REACT_DECEL = 4.0  # m/s^2
AEB_TTC = 2.0  # s
AEB_GAP = 5.0  # m
AEB_DECEL = 8.0  # m/s^2

Policy = Callable[[dict], tuple[jax.Array, jax.Array]]


class WorldState(NamedTuple):
    """SoA world state for S scenarios x A agents (all float32 unless noted)."""

    ego_x: jax.Array  # (S,)
    ego_y: jax.Array  # (S,)
    ego_psi: jax.Array  # (S,)
    ego_v: jax.Array  # (S,)
    ag_x: jax.Array  # (S, A)
    ag_y: jax.Array  # (S, A)
    ag_psi: jax.Array  # (S, A)
    ag_v: jax.Array  # (S, A)
    t: jax.Array  # () sim clock, seconds
    collided: jax.Array  # (S,) bool, latched
    min_dist: jax.Array  # (S,) running min signed distance
    min_ttc: jax.Array  # (S,) running min TTC
    violations: jax.Array  # (S,) int32, speeding step count


class ScenarioBatch(NamedTuple):
    """Compiled scenario tensors (initial state + agent scripts)."""

    ego_x0: jax.Array  # (S,)
    ego_y0: jax.Array
    ego_psi0: jax.Array
    ego_v0: jax.Array
    ego_radius: jax.Array  # (S,)
    target_v: jax.Array  # (S,)
    speed_limit: jax.Array  # (S,)
    family_id: jax.Array  # (S,) int32
    ag_x0: jax.Array  # (S, A)
    ag_y0: jax.Array
    ag_psi0: jax.Array
    ag_v0: jax.Array
    ag_radius: jax.Array  # (S, A)
    accel_phases: jax.Array  # (S, A, 3)
    yaw_phases: jax.Array  # (S, A, 3)
    phase_t: jax.Array  # (S, A, 2) switch times
    reactive: jax.Array  # (S, A) 0/1
    valid: jax.Array  # (S, A) 0/1

    @property
    def num_scenarios(self) -> int:
        return self.ego_x0.shape[0]

    def initial_state(self) -> WorldState:
        """Fresh (donation-safe) state buffers for one rollout."""
        S, A = self.valid.shape
        return WorldState(
            ego_x=jnp.array(self.ego_x0),
            ego_y=jnp.array(self.ego_y0),
            ego_psi=jnp.array(self.ego_psi0),
            ego_v=jnp.array(self.ego_v0),
            ag_x=jnp.array(self.ag_x0),
            ag_y=jnp.array(self.ag_y0),
            ag_psi=jnp.array(self.ag_psi0),
            ag_v=jnp.array(self.ag_v0),
            t=jnp.zeros((), jnp.float32),
            collided=jnp.zeros((S,), bool),
            min_dist=jnp.full((S,), TTC_MAX, jnp.float32),
            min_ttc=jnp.full((S,), TTC_MAX, jnp.float32),
            violations=jnp.zeros((S,), jnp.int32),
        )


class RolloutMetrics(NamedTuple):
    collided: jax.Array  # (S,) bool
    min_dist: jax.Array  # (S,)
    min_ttc: jax.Array  # (S,)
    violations: jax.Array  # (S,) int32


# ---------------------------------------------------------------------------
# Built-in policies (the algorithms under test)
# ---------------------------------------------------------------------------


def baseline_policy(obs: dict) -> tuple[jax.Array, jax.Array]:
    """Lane-keep + cruise to target speed; blind to traffic (no AEB)."""
    accel = jnp.clip(1.5 * (obs["target_v"] - obs["v"]), -3.0, 2.0)
    steer = jnp.clip(-0.25 * obs["y"] - 1.2 * obs["psi"], -0.4, 0.4)
    return accel, steer


def aeb_policy(obs: dict) -> tuple[jax.Array, jax.Array]:
    """Baseline + autonomous emergency braking on TTC / forward gap."""
    accel, steer = baseline_policy(obs)
    brake = (obs["min_ttc"] < AEB_TTC) | (obs["min_gap"] < AEB_GAP)
    return jnp.where(brake, -AEB_DECEL, accel), steer


# ---------------------------------------------------------------------------
# World dynamics
# ---------------------------------------------------------------------------


def _collision_signals(state: WorldState, batch: ScenarioBatch, use_pallas: bool):
    ego_pos = jnp.stack([state.ego_x, state.ego_y], -1)
    ego_vel = jnp.stack(
        [state.ego_v * jnp.cos(state.ego_psi), state.ego_v * jnp.sin(state.ego_psi)], -1
    )
    ag_pos = jnp.stack([state.ag_x, state.ag_y], -1)
    ag_vel = jnp.stack(
        [state.ag_v * jnp.cos(state.ag_psi), state.ag_v * jnp.sin(state.ag_psi)], -1
    )
    if use_pallas:
        from repro.kernels.collision.ops import collision_ttc

        dist, ttc, hit = collision_ttc(
            ego_pos, ego_vel, batch.ego_radius, ag_pos, ag_vel, batch.ag_radius
        )
    else:
        dist, ttc, hit = collision_ttc_ref(
            ego_pos, ego_vel, batch.ego_radius, ag_pos, ag_vel, batch.ag_radius
        )
    valid = batch.valid > 0.5
    dist = jnp.where(valid, dist, TTC_MAX)
    ttc = jnp.where(valid, ttc, TTC_MAX)
    hit = hit & valid
    # forward gap: nearest valid agent ahead of the ego (for AEB / obs)
    rel_x = ag_pos[..., 0] - state.ego_x[:, None]
    rel_y = ag_pos[..., 1] - state.ego_y[:, None]
    ahead = (
        rel_x * jnp.cos(state.ego_psi)[:, None] + rel_y * jnp.sin(state.ego_psi)[:, None]
    ) > 0.0
    gap = jnp.where(valid & ahead, dist, TTC_MAX)
    return dist, ttc, hit, gap


def _step_agents(state: WorldState, batch: ScenarioBatch, dt: float):
    """Advance scripted agents one tick (three-phase accel/yaw profiles)."""
    t = state.t
    t1, t2 = batch.phase_t[..., 0], batch.phase_t[..., 1]

    def phased(p):  # (S, A, 3) -> (S, A) by sim-time phase
        return jnp.where(t < t1, p[..., 0], jnp.where(t < t2, p[..., 1], p[..., 2]))

    a_cmd = phased(batch.accel_phases)
    w_cmd = phased(batch.yaw_phases)

    # reactive agents brake when the ego sits close ahead in their frame
    dx = state.ego_x[:, None] - state.ag_x
    dy = state.ego_y[:, None] - state.ag_y
    c, s = jnp.cos(state.ag_psi), jnp.sin(state.ag_psi)
    fwd = dx * c + dy * s
    lat = -dx * s + dy * c
    ego_ahead = (fwd > 0.0) & (fwd < REACT_DIST) & (jnp.abs(lat) < 2.0)
    a_cmd = jnp.where((batch.reactive > 0.5) & ego_ahead, -REACT_DECEL, a_cmd)

    psi = state.ag_psi + w_cmd * dt
    v = jnp.clip(state.ag_v + a_cmd * dt, 0.0, V_MAX)
    x = state.ag_x + v * jnp.cos(psi) * dt
    y = state.ag_y + v * jnp.sin(psi) * dt
    return x, y, psi, v


def _step_ego(state: WorldState, accel: jax.Array, steer: jax.Array, dt: float):
    """Kinematic bicycle, semi-implicit Euler."""
    psi = state.ego_psi + state.ego_v / WHEELBASE * jnp.tan(steer) * dt
    v = jnp.clip(state.ego_v + accel * dt, 0.0, V_MAX)
    x = state.ego_x + v * jnp.cos(psi) * dt
    y = state.ego_y + v * jnp.sin(psi) * dt
    return x, y, psi, v


@functools.partial(
    jax.jit,
    static_argnames=("policy", "steps", "dt", "use_pallas"),
    donate_argnums=(0,),
)
def _rollout(
    state: WorldState,
    batch: ScenarioBatch,
    policy: Policy,
    steps: int,
    dt: float,
    use_pallas: bool,
) -> WorldState:
    def body(st: WorldState, _):
        dist, ttc, hit, gap = _collision_signals(st, batch, use_pallas)
        obs = {
            "v": st.ego_v,
            "y": st.ego_y,
            "psi": st.ego_psi,
            "target_v": batch.target_v,
            "min_ttc": jnp.min(ttc, axis=1),
            "min_gap": jnp.min(gap, axis=1),
        }
        accel, steer = policy(obs)
        ex, ey, epsi, ev = _step_ego(st, accel, steer, dt)
        ax, ay, apsi, av = _step_agents(st, batch, dt)
        new = WorldState(
            ego_x=ex, ego_y=ey, ego_psi=epsi, ego_v=ev,
            ag_x=ax, ag_y=ay, ag_psi=apsi, ag_v=av,
            t=st.t + dt,
            collided=st.collided | jnp.any(hit, axis=1),
            min_dist=jnp.minimum(st.min_dist, jnp.min(dist, axis=1)),
            min_ttc=jnp.minimum(st.min_ttc, jnp.min(ttc, axis=1)),
            violations=st.violations + (st.ego_v > batch.speed_limit).astype(jnp.int32),
        )
        return new, None

    final, _ = jax.lax.scan(body, state, None, length=steps)
    # the body checks pre-step states 0..steps-1; fold in the post-step state
    # so a collision landing on the last integration tick isn't missed
    dist, ttc, hit, _ = _collision_signals(final, batch, use_pallas)
    return final._replace(
        collided=final.collided | jnp.any(hit, axis=1),
        min_dist=jnp.minimum(final.min_dist, jnp.min(dist, axis=1)),
        min_ttc=jnp.minimum(final.min_ttc, jnp.min(ttc, axis=1)),
    )


def rollout(
    batch: ScenarioBatch,
    policy: Policy,
    *,
    steps: int = 100,
    dt: float = 0.1,
    use_pallas: bool = False,
) -> tuple[RolloutMetrics, WorldState]:
    """Close the loop: step the full scenario batch ``steps`` ticks under
    ``policy`` and return per-scenario safety metrics + the final state."""
    final = _rollout(batch.initial_state(), batch, policy, steps, float(dt), use_pallas)
    metrics = RolloutMetrics(
        collided=final.collided,
        min_dist=final.min_dist,
        min_ttc=final.min_ttc,
        violations=final.violations,
    )
    return metrics, final
