"""Campaign DAG subsystem: artifact-edged job dependencies over the
platform — the closed-loop qualification factory (see :mod:`.graph`,
:mod:`.driver`, :mod:`.qualification`)."""

from repro.campaign.driver import CampaignDriver
from repro.campaign.graph import (
    ARTIFACT_KINDS,
    Artifact,
    ArtifactRef,
    ArtifactStore,
    CampaignCycleError,
    CampaignError,
    CampaignSpec,
    LegSpec,
    default_shard,
    leg_fingerprint,
    plan_fan_out,
)
from repro.campaign.qualification import qualification_campaign
from repro.campaign.report import (
    LEG_CANCELLED,
    LEG_DONE,
    LEG_FAILED,
    LEG_PENDING,
    LEG_RUNNING,
    LEG_SATISFIED,
    LEG_SKIPPED_CACHED,
    LEG_SKIPPED_GATE,
    LEG_TERMINAL,
    CampaignReport,
    LegReport,
    critical_path,
    render_report,
)

__all__ = [
    "ARTIFACT_KINDS",
    "Artifact",
    "ArtifactRef",
    "ArtifactStore",
    "CampaignCycleError",
    "CampaignDriver",
    "CampaignError",
    "CampaignReport",
    "CampaignSpec",
    "LEG_CANCELLED",
    "LEG_DONE",
    "LEG_FAILED",
    "LEG_PENDING",
    "LEG_RUNNING",
    "LEG_SATISFIED",
    "LEG_SKIPPED_CACHED",
    "LEG_SKIPPED_GATE",
    "LEG_TERMINAL",
    "LegReport",
    "LegSpec",
    "critical_path",
    "default_shard",
    "leg_fingerprint",
    "plan_fan_out",
    "qualification_campaign",
    "render_report",
]
