"""Campaign graphs: typed, versioned artifact edges between platform jobs.

The paper's platform exists to run *pipelines* — simulation sweeps that
gate algorithm deployment, offline training over mined data, HD-map
generation — but a bare :class:`~repro.platform.spec.JobSpec` is an
independent job.  This module declares the dependency structure:

* an :class:`ArtifactRef` names a typed, **content-addressed** output
  (``checkpoint``, ``dataset``, ``verdict``, ``tiles``, ``report``,
  ``blob``) — the version is a hash of the payload bytes, so two runs that
  produce the same data produce the same version, which is how the chaos
  benchmark proves a faulted campaign bitwise-equal to a clean one;
* an :class:`ArtifactStore` persists artifacts over the tiered store
  (:mod:`repro.core.tiered_store`) with a per-leg **memo index**: a leg
  whose fingerprint (bound job spec + consumed artifact versions) was
  already produced is skipped on rerun and its recorded refs reused;
* a :class:`LegSpec` is one campaign leg — a platform job template (or an
  inline ``compute`` function for decision/mining legs) plus
  ``consumes``/``produces`` declarations, optional fan-out expanded from
  pool capacity (generalizing ``--shards auto``), and an optional
  ``gate``: a verdict artifact whose falsy ``passed`` skips the leg;
* a :class:`CampaignSpec` validates the DAG (unique producers, resolvable
  edges, no cycles — a cycle error names the cycle) and yields the
  deterministic topological order the driver schedules in.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from typing import Any, Callable, Optional

import numpy as np

from repro.core import binpipe
from repro.core.tiered_store import TieredStore
from repro.platform.spec import JobSpec

# artifact type vocabulary — the edges of the qualification factory
ARTIFACT_KINDS = ("checkpoint", "dataset", "verdict", "tiles", "report", "blob")

_KIND_FIELD = "__kind__"  # reserved payload field carrying the artifact kind


class CampaignError(ValueError):
    pass


class CampaignCycleError(CampaignError):
    """The leg graph has a dependency cycle; names one concrete cycle."""

    def __init__(self, cycle: list[str]):
        self.cycle = list(cycle)
        super().__init__(
            "campaign graph has a cycle: " + " -> ".join(cycle + cycle[:1])
        )


@dataclasses.dataclass(frozen=True)
class ArtifactRef:
    """A typed, versioned artifact name — what flows along a DAG edge."""

    name: str
    kind: str
    version: str  # content hash (hex) of the payload bytes

    def __str__(self) -> str:
        return f"{self.name}:{self.kind}@{self.version}"


@dataclasses.dataclass
class Artifact:
    """A materialized artifact: its ref plus the decoded payload record."""

    ref: ArtifactRef
    payload: dict


class ArtifactStore:
    """Content-addressed artifact storage + leg memoization over a
    :class:`~repro.core.tiered_store.TieredStore`.

    ``put`` is idempotent per content: the version is a hash over the
    canonically-encoded payload, and a blob that already exists is not
    rewritten — exactly-once production even when chaos makes a leg run
    twice.  ``created`` logs the keys actually written (the exactly-once
    assertion surface for tests).
    """

    def __init__(self, store: Any, prefix: str = "campaign"):
        if isinstance(store, str):
            self.store = TieredStore(store, mem_capacity=1 << 30)
            self._owned = True
        else:
            self.store = store
            self._owned = False
        self.prefix = prefix
        self.created: list[str] = []  # "name@version" for each blob written
        self._lock = threading.Lock()

    # -- keys -----------------------------------------------------------
    def _akey(self, name: str, version: str) -> str:
        return f"{self.prefix}/art/{name}@{version}"

    def _lkey(self, name: str) -> str:
        return f"{self.prefix}/latest/{name}"

    def _mkey(self, leg: str, fingerprint: str) -> str:
        return f"{self.prefix}/memo/{leg}@{fingerprint}"

    # -- artifacts ------------------------------------------------------
    @staticmethod
    def encode_payload(kind: str, payload: dict) -> bytes:
        """Canonical bytes for a payload: kind folded in as a reserved
        field, keys sorted — so the content hash is insertion-order-free."""
        if kind not in ARTIFACT_KINDS:
            raise CampaignError(
                f"unknown artifact kind {kind!r}; known: {ARTIFACT_KINDS}")
        if _KIND_FIELD in payload:
            raise CampaignError(f"{_KIND_FIELD} is a reserved payload field")
        full = dict(payload)
        full[_KIND_FIELD] = kind
        return binpipe.encode_record({k: full[k] for k in sorted(full)})

    def put(self, name: str, kind: str, payload: dict) -> Artifact:
        """Store (idempotently) and return the versioned artifact."""
        data = self.encode_payload(kind, payload)
        version = hashlib.sha256(data).hexdigest()[:16]
        key = self._akey(name, version)
        with self._lock:
            if not self.store.exists(key):
                self.store.put(key, data)
                self.created.append(f"{name}@{version}")
            self.store.put_record(
                self._lkey(name), {"version": version, "kind": kind})
        return Artifact(ArtifactRef(name, kind, version), dict(payload))

    def get(self, name: str, version: Optional[str] = None) -> Optional[Artifact]:
        """Fetch an artifact by name (``@latest`` when version is None)."""
        if version is None:
            latest = self.store.get_record(self._lkey(name))
            if latest is None:
                return None
            version = str(latest["version"])
        data = self.store.get(self._akey(name, version))
        if data is None:
            return None
        payload = binpipe.decode_record(data)
        kind = str(payload.pop(_KIND_FIELD))
        return Artifact(ArtifactRef(name, kind, version), payload)

    def exists(self, name: str, version: str) -> bool:
        return self.store.exists(self._akey(name, version))

    def versions(self, name: str) -> list[str]:
        """All stored versions of an artifact (sorted)."""
        pre = f"{self.prefix}/art/{name}@"
        return sorted(
            k[len(pre):] for k in self.store.keys() if k.startswith(pre))

    # -- leg memoization ------------------------------------------------
    def memo_put(self, leg: str, fingerprint: str,
                 produced: dict[str, ArtifactRef]) -> None:
        refs = {n: [r.kind, r.version] for n, r in sorted(produced.items())}
        self.store.put_record(
            self._mkey(leg, fingerprint), {"refs": json.dumps(refs)})

    def memo_get(self, leg: str,
                 fingerprint: str) -> Optional[dict[str, ArtifactRef]]:
        """Recorded refs for an identical past run of this leg — or None if
        there is no memo or any referenced blob has since been deleted."""
        rec = self.store.get_record(self._mkey(leg, fingerprint))
        if rec is None:
            return None
        refs = {
            n: ArtifactRef(n, k, v)
            for n, (k, v) in json.loads(rec["refs"]).items()
        }
        if not all(self.exists(n, r.version) for n, r in refs.items()):
            return None
        return refs

    def flush(self) -> None:
        self.store.flush()

    def close(self) -> None:
        if self._owned:
            self.store.close()


# ---------------------------------------------------------------------------
# leg + campaign specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LegSpec:
    """One campaign leg: a platform job template *or* an inline compute
    function, plus its artifact edges.

    ``bind(job, inputs)`` specializes the job template to the consumed
    artifacts (e.g. point a serve job at the checkpoint artifact's
    directory) and runs once per leg, before fan-out.  ``shard(job, i, n)``
    derives shard ``i`` of ``n`` from the bound template (the default is
    scenario-aware: it stamps ``shard_index``/``num_shards`` when the
    config has them).  ``harvest(reports, inputs)`` folds the shard
    :class:`JobReport`s (in shard order) into the produced payloads.
    ``compute(inputs)`` replaces all three for local decision/mining legs
    and returns the produced payloads directly.  ``gate`` names a verdict
    artifact (an implicit dependency): a falsy ``passed`` field skips this
    leg — the conditional edge.  ``max_retries`` bounds *campaign-level*
    backfills per shard, on top of the platform's own container retries.
    """

    name: str
    job: Optional[JobSpec] = None
    compute: Optional[Callable[[dict], dict]] = None
    consumes: tuple = ()
    produces: dict = dataclasses.field(default_factory=dict)  # name -> kind
    bind: Optional[Callable[[JobSpec, dict], JobSpec]] = None
    harvest: Optional[Callable[[list, dict], dict]] = None
    gate: Optional[str] = None
    fan_out: Any = 1  # shard count, or "auto" (from the pool's free runs)
    devices_per_shard: int = 2
    shard: Optional[Callable[[JobSpec, int, int], JobSpec]] = None
    max_retries: int = 2

    def validate(self) -> None:
        if (self.job is None) == (self.compute is None):
            raise CampaignError(
                f"leg {self.name!r}: exactly one of job/compute required")
        if self.compute is not None and not self.produces:
            raise CampaignError(
                f"leg {self.name!r}: a compute leg must produce artifacts")
        if self.job is not None and self.produces and self.harvest is None:
            raise CampaignError(
                f"leg {self.name!r}: a producing job leg needs a harvest fn")
        for aname, kind in self.produces.items():
            if kind not in ARTIFACT_KINDS:
                raise CampaignError(
                    f"leg {self.name!r} produces {aname!r} of unknown kind "
                    f"{kind!r}; known: {ARTIFACT_KINDS}")
        if not (self.fan_out == "auto"
                or (isinstance(self.fan_out, int) and self.fan_out >= 1)):
            raise CampaignError(
                f"leg {self.name!r}: fan_out must be >= 1 or 'auto', "
                f"got {self.fan_out!r}")

    def dependencies(self) -> tuple[str, ...]:
        """Artifact names this leg waits on (consumed + the gate verdict)."""
        deps = list(self.consumes)
        if self.gate is not None and self.gate not in deps:
            deps.append(self.gate)
        return tuple(deps)


@dataclasses.dataclass
class CampaignSpec:
    """A named DAG of legs connected by artifact edges."""

    name: str
    legs: tuple = ()

    def __post_init__(self):
        self.legs = tuple(self.legs)

    def validate(self) -> None:
        names = [leg.name for leg in self.legs]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise CampaignError(f"duplicate leg names: {dupes}")
        producers: dict[str, str] = {}
        for leg in self.legs:
            leg.validate()
            for aname in leg.produces:
                if aname in producers:
                    raise CampaignError(
                        f"artifact {aname!r} produced by both "
                        f"{producers[aname]!r} and {leg.name!r}")
                producers[aname] = leg.name
        for leg in self.legs:
            for aname in leg.dependencies():
                if aname not in producers:
                    raise CampaignError(
                        f"leg {leg.name!r} consumes {aname!r}, which no leg "
                        "produces")
                if producers[aname] == leg.name:
                    raise CampaignError(
                        f"leg {leg.name!r} consumes its own output {aname!r}")
        self.topo_order()  # raises CampaignCycleError on a cycle

    def leg(self, name: str) -> LegSpec:
        for leg in self.legs:
            if leg.name == name:
                return leg
        raise KeyError(name)

    def producer_of(self) -> dict[str, str]:
        """Artifact name -> producing leg name."""
        return {
            aname: leg.name for leg in self.legs for aname in leg.produces
        }

    def leg_deps(self) -> dict[str, tuple[str, ...]]:
        """Leg name -> the (sorted, deduplicated) leg names it depends on."""
        producers = self.producer_of()
        return {
            leg.name: tuple(sorted({
                producers[a] for a in leg.dependencies() if a in producers
            }))
            for leg in self.legs
        }

    def dependents_of(self, name: str) -> list[str]:
        """Transitive downstream closure of a leg (sorted) — the legs to
        cascade-cancel when it fails permanently."""
        deps = self.leg_deps()
        out: set[str] = set()
        frontier = [name]
        while frontier:
            cur = frontier.pop()
            for other, ds in deps.items():
                if cur in ds and other not in out:
                    out.add(other)
                    frontier.append(other)
        return sorted(out)

    def topo_order(self) -> list[str]:
        """Deterministic topological order (Kahn, lexicographic ready set).
        Raises :class:`CampaignCycleError` naming a cycle when one exists."""
        deps = self.leg_deps()
        indeg = {n: len(ds) for n, ds in deps.items()}
        dependents: dict[str, list[str]] = {n: [] for n in deps}
        for n, ds in deps.items():
            for d in ds:
                dependents[d].append(n)
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            changed = False
            for m in dependents[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
                    changed = True
            if changed:
                ready.sort()
        if len(order) != len(deps):
            remaining = {n for n in deps if n not in order}
            raise CampaignCycleError(_find_cycle(deps, remaining))
        return order


def _find_cycle(deps: dict[str, tuple[str, ...]], nodes: set) -> list[str]:
    """Extract one concrete cycle from the unsortable remainder (DFS)."""
    state: dict[str, int] = {}  # 0 visiting / 1 done
    stack: list[str] = []

    def visit(n: str) -> Optional[list[str]]:
        state[n] = 0
        stack.append(n)
        for d in deps.get(n, ()):
            if d not in nodes:
                continue
            if state.get(d) == 0:
                return stack[stack.index(d):]
            if d not in state:
                cyc = visit(d)
                if cyc is not None:
                    return cyc
        stack.pop()
        state[n] = 1
        return None

    for n in sorted(nodes):
        if n not in state:
            cyc = visit(n)
            if cyc is not None:
                return cyc
    return sorted(nodes)  # unreachable fallback


# ---------------------------------------------------------------------------
# fan-out planning + fingerprints
# ---------------------------------------------------------------------------


def plan_fan_out(rm, fan_out, devices_per_shard: int = 2) -> int:
    """Shard count for a fan-out leg.  ``"auto"`` derives it from the
    pool's free contiguous runs — the same plan ``--shards auto`` and the
    serve-cell tier use (:func:`repro.launch.cells.serve_cell_plan`), so
    every pool-saturation policy stays in sync."""
    if isinstance(fan_out, str):
        if fan_out.strip().lower() != "auto":
            raise CampaignError(f"fan_out must be an int or 'auto', got {fan_out!r}")
        from repro.launch.cells import serve_cell_plan

        return len(serve_cell_plan(rm, devices_per_cell=devices_per_shard))
    return max(1, int(fan_out))


def default_shard(job: JobSpec, i: int, n: int) -> JobSpec:
    """Derive shard ``i`` of ``n`` from a bound job template: uniquified
    name, and ``shard_index``/``num_shards`` stamped when the config has
    them (the scenario driver's seed-deterministic slicing)."""
    cfg = job.config
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        fields = {f.name for f in dataclasses.fields(cfg)}
        if {"shard_index", "num_shards"} <= fields:
            cfg = dataclasses.replace(cfg, shard_index=i, num_shards=n)
    elif isinstance(cfg, dict) and {"shard_index", "num_shards"} <= set(cfg):
        cfg = {**cfg, "shard_index": i, "num_shards": n}
    return dataclasses.replace(
        job, name=f"{job.name or job.kind}-{i}", config=cfg)


def _json_default(o: Any) -> Any:
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.integer, np.floating)):
        return o.item()
    return str(o)


def leg_fingerprint(leg: LegSpec, bound_job: Optional[JobSpec],
                    consumed: dict[str, ArtifactRef]) -> str:
    """Content fingerprint of a leg *invocation*: the bound (pre-fan-out)
    job spec plus the exact versions it consumes.  Fan-out count is
    deliberately excluded — shard outputs are partition-invariant, so the
    same inputs on a differently-shaped pool still reuse.  For compute
    legs only the function's name participates (a changed body needs a
    renamed function or a cleared memo to invalidate)."""
    body = {
        "leg": leg.name,
        "job": dataclasses.asdict(bound_job) if bound_job is not None else None,
        "compute": (getattr(leg.compute, "__qualname__", repr(leg.compute))
                    if leg.compute is not None else None),
        "consumed": {n: [r.kind, r.version]
                     for n, r in sorted(consumed.items())},
        "produces": dict(sorted(leg.produces.items())),
    }
    blob = json.dumps(body, sort_keys=True, default=_json_default)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:16]
