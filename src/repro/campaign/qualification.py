"""The closed-loop qualification campaign: the paper's five services wired
into one DAG.

    sweep ──rollouts_baseline──▶ dataset ──mined_dataset──▶ train
      │                                                       │
      ├─rollouts_candidate─▶ qualify ──verdict (gate)──┐      │checkpoint
      └─rollouts_baseline──▶    │                      ▼      ▼
                                └─────────────▶      rollout (serve)

* **sweep** — one fan-out scenario leg that runs *both* policies: the
  first ``ceil(n/2)`` shards cover the full scenario set with the deployed
  baseline, the rest cover it again with the candidate.  Because each half
  re-partitions the same seed-deterministic batch, the harvested rollout
  records are partition-invariant — any shard count ≥ 2 produces
  bitwise-identical artifacts.
* **dataset** — a compute leg that mines the near-miss scenarios
  (collision, low TTC, or rule violation) out of the baseline rollouts:
  the "drive data in, model out" edge.
* **train** — a train job whose checkpoint directory is derived from the
  mined dataset's *version*, so retraining happens exactly when the mined
  data changes; produces a ``checkpoint`` artifact versioned by the final
  parameter digest.
* **qualify** — a compute decision leg running the A/B gate
  (:func:`repro.scenario.metrics.qualify`) over both rollout records; its
  ``verdict`` artifact carries ``passed``.
* **rollout** — a serve job **gated on the verdict**: it restores the
  checkpoint artifact and generates with seeded sampling; the produced
  report content-hashes the generated tokens.  A failed gate skips this
  leg (and the campaign still completes DONE).
"""

from __future__ import annotations

import dataclasses
import json
import os
from types import SimpleNamespace

import numpy as np

from repro.campaign.graph import CampaignSpec, LegSpec
from repro.platform.spec import JobSpec


def _cat_rollouts(metric_dicts: list) -> tuple:
    """Concatenate shard metrics (shard order == scenario order) into
    (family_ids, family_names, rollout-like, steps)."""
    fam = np.concatenate(
        [np.asarray(m["_family_id"]) for m in metric_dicts])
    roll = SimpleNamespace(**{
        f: np.concatenate(
            [np.asarray(getattr(m["_rollout"], f)) for m in metric_dicts])
        for f in ("collided", "min_ttc", "min_dist", "violations")
    })
    return fam, metric_dicts[0]["_family_names"], roll, int(
        metric_dicts[0]["steps"])


def _sweep_shard(baseline: str, candidate: str):
    """Shard fn: split ``n`` shards into a baseline half and a candidate
    half, each independently re-sharding the full scenario batch."""

    def shard(job: JobSpec, i: int, n: int) -> JobSpec:
        if n < 2:
            raise ValueError(
                f"the A/B sweep needs fan_out >= 2 (one shard per policy "
                f"half), got {n}")
        b = (n + 1) // 2
        policy, local_i, local_n, tag = (
            (baseline, i, b, "base") if i < b
            else (candidate, i - b, n - b, "cand"))
        cfg = dataclasses.replace(
            job.config, policy=policy, shard_index=local_i,
            num_shards=local_n)
        return dataclasses.replace(
            job, name=f"{job.name or job.kind}-{tag}{local_i}", config=cfg)

    return shard


def _harvest_sweep(reports: list, inputs: dict) -> dict:
    from repro.scenario.metrics import rollout_record

    n = len(reports)
    b = (n + 1) // 2  # mirrors _sweep_shard's split
    out = {}
    for aname, ms in (
        ("rollouts_baseline", [r.metrics for r in reports[:b]]),
        ("rollouts_candidate", [r.metrics for r in reports[b:]]),
    ):
        fam, names, roll, steps = _cat_rollouts(ms)
        out[aname] = rollout_record(fam, names, roll, steps=steps)
    return out


def _mine_dataset(near_miss_ttc: float):
    """Compute leg: near-miss mining over the baseline rollouts — the
    scenarios worth retraining on (collision, TTC under threshold, or any
    rule violation)."""

    def mine(inputs: dict) -> dict:
        rec = inputs["rollouts_baseline"].payload
        collided = np.asarray(rec["collided"]).astype(bool)
        min_ttc = np.asarray(rec["min_ttc"])
        violations = np.asarray(rec["violations"])
        hard = collided | (min_ttc < near_miss_ttc) | (violations > 0)
        idx = np.flatnonzero(hard).astype(np.int64)
        return {"mined_dataset": {
            "indices": idx,
            "count": int(idx.size),
            "total": int(hard.size),
            "near_miss_ttc": float(near_miss_ttc),
            "source": str(inputs["rollouts_baseline"].ref),
        }}

    return mine


def _bind_train(ckpt_root: str):
    def bind(job: JobSpec, inputs: dict) -> JobSpec:
        # the checkpoint directory is keyed by the mined dataset's version:
        # a changed dataset gets a fresh directory (no stale resume), an
        # unchanged one re-lands on the same deterministic path
        sub = f"train-{inputs['mined_dataset'].ref.version}"
        cfg = dataclasses.replace(
            job.config, ckpt_dir=os.path.join(ckpt_root, sub))
        return dataclasses.replace(job, config=cfg)

    return bind


def _harvest_train(reports: list, inputs: dict) -> dict:
    m = reports[0].metrics
    # the subpath (not the absolute dir) goes in the payload, so the
    # artifact version is machine- and tmpdir-independent
    return {"checkpoint": {
        "ckpt": f"train-{inputs['mined_dataset'].ref.version}",
        "step": int(m["steps"]),
        "params_digest": str(m["params_digest"]),
    }}


def _qualify(inputs: dict) -> dict:
    from repro.scenario.metrics import qualify, report_from_record

    q = qualify(
        report_from_record(inputs["rollouts_baseline"].payload),
        report_from_record(inputs["rollouts_candidate"].payload),
    )
    return {"verdict": {
        "passed": int(q.passed),
        "baseline_collision_rate": float(q.baseline_collision_rate),
        "candidate_collision_rate": float(q.candidate_collision_rate),
        "reasons": json.dumps(q.reasons),
    }}


def _bind_rollout(ckpt_root: str):
    def bind(job: JobSpec, inputs: dict) -> JobSpec:
        cfg = dataclasses.replace(
            job.config,
            ckpt_dir=os.path.join(ckpt_root, inputs["checkpoint"].payload["ckpt"]))
        return dataclasses.replace(job, config=cfg)

    return bind


def _harvest_rollout(reports: list, inputs: dict) -> dict:
    m = reports[0].metrics
    # generated token ids only — seeded sampling makes them a pure function
    # of the checkpoint params; timing metrics stay out of the payload
    return {"serve_rollout": {
        "tokens_out": np.asarray(m["_tokens"]),
        "tokens": int(m["tokens"]),
        "checkpoint": str(inputs["checkpoint"].ref),
    }}


def qualification_campaign(
    *,
    ckpt_root: str,
    name: str = "qualification",
    arch: str = "qwen2-0.5b",
    families=None,
    per_family: int = 8,
    scenario_steps: int = 40,
    baseline_policy: str = "baseline",
    candidate_policy: str = "aeb",
    fan_out=4,
    devices_per_shard: int = 2,
    train_steps: int = 6,
    train_batch: int = 4,
    train_seq: int = 64,
    serve_gen: int = 8,
    seed: int = 0,
    max_retries: int = 2,
) -> CampaignSpec:
    """Build the five-leg closed-loop qualification campaign.

    Swapping ``baseline_policy``/``candidate_policy`` (so the candidate is
    the *worse* planner) exercises the gate's false branch: ``qualify``
    rejects, the ``rollout`` leg is skipped, and the campaign still
    completes.
    """
    from repro.platform.services import (
        ScenarioJobConfig,
        ServeJobConfig,
        TrainJobConfig,
    )

    vocab = 512
    sweep = LegSpec(
        name="sweep",
        job=JobSpec(
            kind="scenario",
            name=f"{name}-sweep",
            config=ScenarioJobConfig(
                families=families, per_family=per_family,
                steps=scenario_steps, seed=seed, policy=baseline_policy,
            ),
            devices=devices_per_shard,
        ),
        produces={"rollouts_baseline": "dataset",
                  "rollouts_candidate": "dataset"},
        harvest=_harvest_sweep,
        shard=_sweep_shard(baseline_policy, candidate_policy),
        fan_out=fan_out,
        devices_per_shard=devices_per_shard,
        max_retries=max_retries,
    )
    dataset = LegSpec(
        name="dataset",
        compute=_mine_dataset(near_miss_ttc=2.0),
        consumes=("rollouts_baseline",),
        produces={"mined_dataset": "dataset"},
    )
    train = LegSpec(
        name="train",
        job=JobSpec(
            kind="train",
            name=f"{name}-train",
            config=TrainJobConfig(
                arch=arch, steps=train_steps, batch=train_batch,
                seq=train_seq, vocab=vocab, ckpt_every=max(train_steps // 2, 1),
                log_every=max(train_steps // 2, 1),
            ),
            devices=devices_per_shard,
        ),
        consumes=("mined_dataset",),
        produces={"checkpoint": "checkpoint"},
        bind=_bind_train(ckpt_root),
        harvest=_harvest_train,
        devices_per_shard=devices_per_shard,
        max_retries=max_retries,
    )
    gate = LegSpec(
        name="qualify",
        compute=_qualify,
        consumes=("rollouts_baseline", "rollouts_candidate"),
        produces={"verdict": "verdict"},
    )
    rollout = LegSpec(
        name="rollout",
        job=JobSpec(
            kind="serve",
            name=f"{name}-rollout",
            config=ServeJobConfig(
                arch=arch, engine="static", temperature=0.0, seed=seed,
                batch=2, prompt_len=16, gen=serve_gen, vocab=vocab,
                # the model config is shaped by (arch, vocab, seq): restore
                # only round-trips when these match the train job's
                seq=train_seq,
            ),
            devices=devices_per_shard,
        ),
        consumes=("checkpoint",),
        gate="verdict",
        produces={"serve_rollout": "report"},
        bind=_bind_rollout(ckpt_root),
        harvest=_harvest_rollout,
        devices_per_shard=devices_per_shard,
        max_retries=max_retries,
    )
    return CampaignSpec(name=name, legs=(sweep, dataset, train, gate, rollout))
