"""Human-readable campaign reports: per-leg status, retries, artifact
versions, and the DAG critical path.

The :class:`CampaignReport` is the campaign analog of the per-job
:class:`~repro.platform.spec.JobReport`: one uniform record the CLI, the
benchmark and CI smoke all render with :func:`render_report` — the
orchestrator/reporter "daily experiment report" shape.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# leg states (campaign level; a leg's shards are platform jobs underneath)
LEG_PENDING = "PENDING"
LEG_RUNNING = "RUNNING"
LEG_DONE = "DONE"
LEG_FAILED = "FAILED"
LEG_CANCELLED = "CANCELLED"
LEG_SKIPPED_GATE = "SKIPPED_GATE"    # gate verdict said no
LEG_SKIPPED_CACHED = "SKIPPED_CACHED"  # unchanged inputs: artifacts reused
LEG_TERMINAL = (LEG_DONE, LEG_FAILED, LEG_CANCELLED,
                LEG_SKIPPED_GATE, LEG_SKIPPED_CACHED)
# states that satisfy a downstream dependency (artifacts are available)
LEG_SATISFIED = (LEG_DONE, LEG_SKIPPED_CACHED)


@dataclasses.dataclass
class LegReport:
    """One leg's outcome: shards, campaign-level retries, artifacts."""

    name: str
    state: str
    shards: list[str] = dataclasses.field(default_factory=list)
    retries: int = 0  # campaign-level backfills (beyond platform retries)
    platform_retries: int = 0  # container-failure retries inside the shards
    artifacts: dict[str, str] = dataclasses.field(default_factory=dict)
    # name -> "kind@version"
    error: Optional[str] = None
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    reused: bool = False

    @property
    def wall_s(self) -> float:
        if self.started_at is None or self.finished_at is None:
            return 0.0
        return max(self.finished_at - self.started_at, 0.0)


@dataclasses.dataclass
class CampaignReport:
    """The whole campaign's outcome, legs in topological order."""

    name: str
    state: str  # DONE | FAILED
    legs: dict[str, LegReport] = dataclasses.field(default_factory=dict)
    wall_s: float = 0.0
    critical_path: list[str] = dataclasses.field(default_factory=list)

    @property
    def artifacts(self) -> dict[str, str]:
        out: dict[str, str] = {}
        for leg in self.legs.values():
            out.update(leg.artifacts)
        return out


def critical_path(legs: dict[str, LegReport],
                  deps: dict[str, tuple]) -> list[str]:
    """The chain of legs that determined the campaign's end time: start
    from the leg that finished last, repeatedly step to the dependency
    that finished last, and reverse.  Legs that never started (skipped or
    cancelled before running) are transparent — the walk continues through
    their dependencies."""
    finished = {
        n: r.finished_at for n, r in legs.items() if r.finished_at is not None
    }
    if not finished:
        return []
    path: list[str] = []
    cur: Optional[str] = max(sorted(finished), key=lambda n: finished[n])
    while cur is not None:
        if legs[cur].started_at is not None or not path:
            path.append(cur)
        prev = [d for d in deps.get(cur, ()) if d in finished]
        cur = max(sorted(prev), key=lambda n: finished[n]) if prev else None
    return list(reversed(path))


def render_report(report: CampaignReport) -> str:
    """Render the campaign report — the artifact CI uploads."""
    lines = [
        f"campaign {report.name}: {report.state} "
        f"({len(report.legs)} legs, wall {report.wall_s:.2f}s)",
        "",
        f"{'leg':<12} {'state':<15} {'shards':>6} {'retries':>8} "
        f"{'wall_s':>8}  artifacts",
    ]
    for name, leg in report.legs.items():
        arts = " ".join(
            f"{a}={v}" for a, v in sorted(leg.artifacts.items())) or "-"
        retries = f"{leg.retries}+{leg.platform_retries}"
        lines.append(
            f"{name:<12} {leg.state:<15} {len(leg.shards):>6} "
            f"{retries:>8} {leg.wall_s:>8.2f}  {arts}"
        )
        if leg.error:
            lines.append(f"{'':<12} error: {leg.error}")
    lines.append("")
    if report.critical_path:
        lines.append("critical path: " + " -> ".join(report.critical_path))
        cp_wall = sum(report.legs[n].wall_s for n in report.critical_path
                      if n in report.legs)
        lines.append(
            f"critical path wall: {cp_wall:.2f}s of {report.wall_s:.2f}s "
            "campaign wall"
        )
    return "\n".join(lines)
