"""The campaign driver: event-driven DAG execution over the platform.

One :class:`CampaignDriver` drives a :class:`~repro.campaign.graph
.CampaignSpec` to completion on a shared :class:`~repro.platform.client
.Platform`:

* legs are submitted the moment their dependencies' artifacts land —
  the driver blocks in :meth:`Platform.wait_any`, which is event-driven
  off the PR-5 ``ResourceManager`` listeners (no polling loop of its own);
* fan-out legs expand into shard jobs planned from pool capacity
  (:func:`~repro.campaign.graph.plan_fan_out`), keyed strictly by the
  *returned* uniquified job names so concurrent campaigns can share one
  platform;
* a failed shard is **backfilled** — resubmitted alone after a seeded
  exponential-backoff hold (the PR-6 retry curve), up to the leg's
  ``max_retries``, while sibling shards keep running; a permanently
  failed leg cancels its still-running siblings and cascade-cancels every
  transitive dependent (independent branches continue);
* gate legs consume a ``verdict`` artifact: a falsy ``passed`` skips the
  leg (and, transitively, everything that needed its outputs) —
  the conditional edge;
* a leg whose fingerprint (bound spec + consumed versions) already has a
  memo in the :class:`~repro.campaign.graph.ArtifactStore` is skipped and
  its recorded artifacts reused;
* every leg runs under a ``campaign.leg`` span (child of one ``campaign``
  root span), with submit/retry/skip/artifact events — the Perfetto
  timeline shows the whole DAG critical path.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Optional

from repro.campaign.graph import (
    Artifact,
    ArtifactStore,
    CampaignSpec,
    LegSpec,
    default_shard,
    leg_fingerprint,
    plan_fan_out,
)
from repro.campaign.report import (
    LEG_CANCELLED,
    LEG_DONE,
    LEG_FAILED,
    LEG_PENDING,
    LEG_RUNNING,
    LEG_SATISFIED,
    LEG_SKIPPED_CACHED,
    LEG_SKIPPED_GATE,
    LEG_TERMINAL,
    CampaignReport,
    LegReport,
    critical_path,
    render_report,
)
from repro.platform.client import CANCELLED, DONE, FAILED, Platform
from repro.platform.spec import JobSpec


@dataclasses.dataclass
class _Leg:
    spec: LegSpec
    state: str = LEG_PENDING
    shard_specs: list = dataclasses.field(default_factory=list)
    shard_jobs: list = dataclasses.field(default_factory=list)  # uniquified
    shard_done: list = dataclasses.field(default_factory=list)
    attempts: dict = dataclasses.field(default_factory=dict)  # shard -> subs
    retries: int = 0  # campaign-level backfills across all shards
    platform_retries: int = 0
    inputs: dict = dataclasses.field(default_factory=dict)  # name -> Artifact
    artifacts: dict = dataclasses.field(default_factory=dict)  # name -> ref
    fingerprint: Optional[str] = None
    error: Optional[str] = None
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    reused: bool = False
    span: object = None


class CampaignDriver:
    """Plans and drives one campaign DAG on a platform + artifact store."""

    def __init__(
        self,
        platform: Platform,
        spec: CampaignSpec,
        store: ArtifactStore,
        *,
        name: Optional[str] = None,
        backoff_s: float = 0.05,
        backoff_cap_s: float = 2.0,
        backoff_seed: int = 0,
        reuse: bool = True,
        shard_timeout_s: float = 600.0,
    ):
        spec.validate()
        self.platform = platform
        self.spec = spec
        self.store = store
        self.name = name or spec.name
        self.reuse = reuse
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self.shard_timeout_s = shard_timeout_s
        self._rng = random.Random(backoff_seed)
        self._order = spec.topo_order()
        self._deps = spec.leg_deps()
        self._legs = {n: _Leg(spec.leg(n)) for n in self._order}
        self._artifacts: dict[str, Artifact] = {}
        self._outstanding: dict[str, tuple[str, int]] = {}  # job -> (leg, i)
        self._holds: dict[tuple[str, int], float] = {}  # (leg, i) -> resub at
        self._root = None

    # -- public ---------------------------------------------------------
    def run(self) -> CampaignReport:
        """Drive the DAG until every leg is terminal; returns the report."""
        p = self.platform
        t0 = time.monotonic()
        self._root = p.tracer.start(
            "campaign", job=self.name, legs=len(self._order))
        while True:
            self._advance()
            if all(l.state in LEG_TERMINAL for l in self._legs.values()):
                break
            bound = self._next_hold_delay()
            outstanding = list(self._outstanding)
            if not outstanding and bound is None:
                # defense in depth: _advance must always either finish the
                # campaign or leave work in flight / on a retry hold
                raise RuntimeError(
                    f"campaign {self.name}: no runnable legs but "
                    f"{[n for n, l in self._legs.items() if l.state not in LEG_TERMINAL]} "
                    "not terminal")
            done = p.wait_any(
                outstanding, timeout_s=self.shard_timeout_s,
                return_after_s=bound)
            self._release_holds()
            for job in done:
                self._on_job_terminal(job)
        state = (
            DONE
            if all(l.state in (LEG_DONE, LEG_SKIPPED_CACHED, LEG_SKIPPED_GATE)
                   for l in self._legs.values())
            else FAILED
        )
        p.tracer.tag(self._root, state=state)
        p.tracer.end(self._root)
        p.obs.inc(f"campaigns_{state.lower()}")
        wall = time.monotonic() - t0
        legs = {
            n: LegReport(
                name=n, state=l.state, shards=list(l.shard_jobs),
                retries=l.retries, platform_retries=l.platform_retries,
                artifacts={a: f"{r.kind}@{r.version}"
                           for a, r in sorted(l.artifacts.items())},
                error=l.error, started_at=l.started_at,
                finished_at=l.finished_at, reused=l.reused,
            )
            for n, l in ((n, self._legs[n]) for n in self._order)
        }
        return CampaignReport(
            name=self.name, state=state, legs=legs, wall_s=wall,
            critical_path=critical_path(legs, self._deps),
        )

    def render(self, report: CampaignReport) -> str:
        return render_report(report)

    # -- scheduling -----------------------------------------------------
    def _advance(self) -> None:
        """Start every leg whose dependencies are satisfied; cascade skips
        and cancellations.  Loops to a fixed point so a chain of compute
        legs completes in one call."""
        progressed = True
        while progressed:
            progressed = False
            for name in self._order:
                leg = self._legs[name]
                if leg.state != LEG_PENDING:
                    continue
                dep_states = [self._legs[d].state for d in self._deps[name]]
                if any(s in (LEG_FAILED, LEG_CANCELLED) for s in dep_states):
                    bad = [d for d in self._deps[name]
                           if self._legs[d].state in (LEG_FAILED, LEG_CANCELLED)]
                    self._settle(leg, LEG_CANCELLED,
                                 error=f"upstream leg(s) failed: {bad}")
                    progressed = True
                elif any(s == LEG_SKIPPED_GATE for s in dep_states):
                    self._settle(leg, LEG_SKIPPED_GATE)
                    progressed = True
                elif all(s in LEG_SATISFIED for s in dep_states):
                    self._start_leg(leg)
                    progressed = True

    def _start_leg(self, leg: _Leg) -> None:
        p = self.platform
        spec = leg.spec
        leg.inputs = {
            a: self._artifacts[a] for a in spec.dependencies()
        }
        consumed = {a: art.ref for a, art in leg.inputs.items()}
        leg.span = p.tracer.start(
            "campaign.leg", job=self.name, parent=self._root,
            leg=spec.name, track=spec.name,
        )
        # conditional edge: the gate verdict selects whether this leg runs
        if spec.gate is not None:
            verdict = leg.inputs[spec.gate]
            if not verdict.payload.get("passed"):
                p.tracer.event(
                    leg.span, "leg_skip_gate", gate=spec.gate,
                    version=verdict.ref.version)
                self._settle(leg, LEG_SKIPPED_GATE)
                return
        bound = None
        if spec.job is not None:
            bound = dataclasses.replace(spec.job)
            if spec.bind is not None:
                bound = spec.bind(bound, leg.inputs)
            if bound.name is None:
                bound = dataclasses.replace(
                    bound, name=f"{self.name}-{spec.name}")
        leg.fingerprint = leg_fingerprint(spec, bound, consumed)
        # artifact reuse: unchanged inputs -> the leg is skipped outright
        if self.reuse:
            refs = self.store.memo_get(spec.name, leg.fingerprint)
            if refs is not None:
                arts = {n: self.store.get(n, r.version) for n, r in refs.items()}
                if all(a is not None for a in arts.values()):
                    leg.started_at = leg.finished_at = time.monotonic()
                    leg.reused = True
                    for n, art in arts.items():
                        self._register_artifact(leg, art)
                    p.tracer.event(
                        leg.span, "leg_reuse", fingerprint=leg.fingerprint)
                    p.obs.inc("campaign_legs_reused")
                    self._settle(leg, LEG_SKIPPED_CACHED)
                    return
        leg.started_at = time.monotonic()
        if spec.compute is not None:
            self._run_compute(leg)
            return
        self._submit_shards(leg, bound)

    def _run_compute(self, leg: _Leg) -> None:
        """Local decision/mining leg: runs inline, inside its span."""
        p = self.platform
        try:
            produced = leg.spec.compute(dict(leg.inputs))
            self._produce(leg, produced)
        except Exception as e:
            self._settle(leg, LEG_FAILED, error=f"{type(e).__name__}: {e}")
            return
        self._settle(leg, LEG_DONE)

    def _submit_shards(self, leg: _Leg, bound: JobSpec) -> None:
        p = self.platform
        spec = leg.spec
        n = plan_fan_out(p.rm, spec.fan_out, spec.devices_per_shard)
        shard_fn = spec.shard or default_shard
        leg.shard_specs, leg.shard_jobs, leg.shard_done = [], [], []
        for i in range(n):
            sspec = shard_fn(bound, i, n)
            sspec = dataclasses.replace(sspec, labels={
                **sspec.labels, "campaign": self.name,
                "leg": spec.name, "shard": str(i),
            })
            # key by the *returned* uniquified name — a concurrent campaign
            # submitting the same shard names must not cross our bookkeeping
            job = p.submit(sspec)
            leg.shard_specs.append(sspec)
            leg.shard_jobs.append(job)
            leg.shard_done.append(False)
            leg.attempts[i] = 1
            self._outstanding[job] = (spec.name, i)
        leg.state = LEG_RUNNING
        p.tracer.event(leg.span, "leg_submit", shards=n)
        p.obs.inc("campaign_legs_submitted")

    # -- completions ----------------------------------------------------
    def _on_job_terminal(self, job: str) -> None:
        p = self.platform
        if job not in self._outstanding:
            return
        leg_name, i = self._outstanding.pop(job)
        leg = self._legs[leg_name]
        rep = p.results(job)
        leg.platform_retries += rep.retries
        if leg.state in LEG_TERMINAL:
            return  # a cancelled sibling draining after the leg settled
        if rep.state == DONE:
            leg.shard_done[i] = True
            if all(leg.shard_done):
                self._harvest(leg)
            return
        # FAILED (or externally CANCELLED) shard: backfill it alone if the
        # campaign-level retry budget allows, else fail the leg
        retries_done = leg.attempts[i] - 1
        if rep.state == FAILED and retries_done < leg.spec.max_retries:
            delay = self._backoff(retries_done + 1)
            self._holds[(leg_name, i)] = time.monotonic() + delay
            leg.retries += 1
            p.tracer.event(
                leg.span, "leg_retry", shard=i, attempt=leg.attempts[i] + 1,
                delay_s=round(delay, 4), error=str(rep.error))
            p.obs.inc("campaign_backfills")
            return
        why = ("cancelled" if rep.state == CANCELLED
               else f"retries exhausted: {rep.error}")
        # cancel still-running siblings; their terminal events drain through
        # _on_job_terminal and are ignored (leg already terminal)
        for other, (ln, _si) in list(self._outstanding.items()):
            if ln == leg_name:
                p.cancel(other)
        for key in [k for k in self._holds if k[0] == leg_name]:
            del self._holds[key]
        self._settle(leg, LEG_FAILED, error=f"shard {i} {why}")

    def _harvest(self, leg: _Leg) -> None:
        """All shards DONE: fold their reports into the produced artifacts
        (exactly once — the leg settles before any duplicate event could
        re-enter)."""
        p = self.platform
        reports = [p.results(j) for j in leg.shard_jobs]
        if leg.spec.produces:
            try:
                produced = leg.spec.harvest(reports, dict(leg.inputs))
                self._produce(leg, produced)
            except Exception as e:
                self._settle(leg, LEG_FAILED,
                             error=f"harvest {type(e).__name__}: {e}")
                return
        self._settle(leg, LEG_DONE)

    def _produce(self, leg: _Leg, produced: dict) -> None:
        declared = set(leg.spec.produces)
        if set(produced) != declared:
            raise ValueError(
                f"leg {leg.spec.name!r} declared {sorted(declared)} but "
                f"produced {sorted(produced)}")
        for aname in sorted(produced):
            art = self.store.put(
                aname, leg.spec.produces[aname], produced[aname])
            self._register_artifact(leg, art)
        if leg.fingerprint is not None:
            self.store.memo_put(leg.spec.name, leg.fingerprint, leg.artifacts)

    def _register_artifact(self, leg: _Leg, art: Artifact) -> None:
        leg.artifacts[art.ref.name] = art.ref
        self._artifacts[art.ref.name] = art
        self.platform.tracer.event(
            leg.span, "artifact", artifact=art.ref.name, kind=art.ref.kind,
            version=art.ref.version)

    def _settle(self, leg: _Leg, state: str, error: Optional[str] = None) -> None:
        p = self.platform
        leg.state = state
        leg.error = error
        if leg.started_at is not None and leg.finished_at is None:
            leg.finished_at = time.monotonic()
        if leg.span is None:  # cascaded skip/cancel before the leg started
            leg.span = p.tracer.start(
                "campaign.leg", job=self.name, parent=self._root,
                leg=leg.spec.name, track=leg.spec.name)
        p.tracer.tag(leg.span, state=state)
        p.tracer.end(leg.span)
        p.obs.inc(f"campaign_legs_{state.lower()}")

    # -- backfill holds -------------------------------------------------
    def _backoff(self, retry: int) -> float:
        """Seeded exponential backoff + jitter for the ``retry``-th
        campaign-level backfill — the same curve the platform uses for
        container-failure resubmission."""
        base = self.backoff_s
        if base <= 0:
            return 0.0
        delay = min(self.backoff_cap_s, base * (2 ** (retry - 1)))
        return delay * (0.5 + self._rng.random())

    def _next_hold_delay(self) -> Optional[float]:
        if not self._holds:
            return None
        return max(min(self._holds.values()) - time.monotonic(), 0.0) + 0.002

    def _release_holds(self) -> None:
        now = time.monotonic()
        p = self.platform
        for (leg_name, i), at in sorted(self._holds.items()):
            if at > now:
                continue
            del self._holds[(leg_name, i)]
            leg = self._legs[leg_name]
            if leg.state in LEG_TERMINAL:
                continue
            job = p.submit(leg.shard_specs[i])
            leg.shard_jobs[i] = job
            leg.attempts[i] += 1
            self._outstanding[job] = (leg_name, i)
