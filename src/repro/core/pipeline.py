"""Stage/Pipeline runtime — the Spark-vs-MapReduce story (paper §2.1, §4.1).

A *job* is a sequence of named stages, each a JAX-traceable function from an
array pytree to an array pytree.  Two execution modes:

* ``FUSED``  — the whole pipeline is one jitted program; intermediates stay
  on device (HBM) exactly like Spark keeps RDDs in memory between stages.
* ``STAGED`` — each stage is jitted separately and every boundary round-trips
  through host memory and (optionally) a store write+read, which is the
  MapReduce/HDFS dataflow the paper measured 5x *against*.

The mapgen and training services build their pipelines on this runtime; the
fused/staged benchmark reproduces the paper's Figure-7/§5.2 comparisons.
"""

from __future__ import annotations

import dataclasses
import io
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.tiered_store import TieredStore


@dataclasses.dataclass
class Stage:
    name: str
    fn: Callable[[Any], Any]  # pytree -> pytree, jax-traceable


def _to_host(tree: Any) -> Any:
    return jax.tree.map(np.asarray, tree)


def _store_roundtrip(store: TieredStore, key: str, tree: Any) -> Any:
    """Serialize a pytree through the store (the 'write to HDFS' boundary)."""
    leaves, treedef = jax.tree.flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, **{f"a{i}": np.asarray(x) for i, x in enumerate(leaves)})
    store.put(key, buf.getvalue())
    data = store.get(key)
    loaded = np.load(io.BytesIO(data))
    return jax.tree.unflatten(treedef, [loaded[f"a{i}"] for i in range(len(leaves))])


class Pipeline:
    def __init__(self, stages: list[Stage], name: str = "pipeline"):
        if not stages:
            raise ValueError("empty pipeline")
        self.stages = stages
        self.name = name
        self._fused = None
        self._staged: Optional[list] = None

    # ------------------------------------------------------------------
    def _compose(self):
        def run(x):
            for s in self.stages:
                x = s.fn(x)
            return x

        return run

    def run_fused(self, inputs: Any) -> Any:
        """One jit for the whole job; intermediates never leave the device."""
        if self._fused is None:
            self._fused = jax.jit(self._compose())
        return self._fused(inputs)

    def run_staged(self, inputs: Any, store: Optional[TieredStore] = None) -> Any:
        """Per-stage jit with host (and optional store) round-trips between
        stages — the tailored-per-application baseline."""
        if self._staged is None:
            self._staged = [jax.jit(s.fn) for s in self.stages]
        x = inputs
        for i, (stage, jitted) in enumerate(zip(self.stages, self._staged)):
            x = jitted(x)
            x = _to_host(jax.block_until_ready(x))
            if store is not None and i < len(self.stages) - 1:
                x = _store_roundtrip(store, f"{self.name}_stage{i}", x)
        return x

    # ------------------------------------------------------------------
    def time_modes(
        self, inputs: Any, store: Optional[TieredStore] = None, iters: int = 3
    ) -> dict[str, float]:
        """Benchmark helper: seconds per run for fused vs staged execution."""
        out = {}
        # warm up compiles outside the timed region
        jax.block_until_ready(self.run_fused(inputs))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(self.run_fused(inputs))
        out["fused_s"] = (time.perf_counter() - t0) / iters

        self.run_staged(inputs, store)
        t0 = time.perf_counter()
        for _ in range(iters):
            self.run_staged(inputs, store)
        out["staged_s"] = (time.perf_counter() - t0) / iters
        out["speedup"] = out["staged_s"] / max(out["fused_s"], 1e-12)
        return out
