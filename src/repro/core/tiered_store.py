"""Tiered in-memory-first storage (the Alluxio role, paper §2.2).

Tiers mirror Alluxio's MEM / SSD / HDD hierarchy with a persistent
"remote" backing store underneath:

    MEM     — python dict (memory-speed)
    SSD     — local directory (fast disk)
    HDD     — local directory (slow disk; optional artificial latency)
    PERSIST — directory standing in for the remote persistent store
              (HDFS in the paper); written *asynchronously* by a
              write-back thread, exactly the paper's co-located-cache
              deployment: "compute nodes read from and write to Alluxio;
              Alluxio then asynchronously persists data into the remote
              storage nodes."

Writes land in the highest tier with space; LRU blocks demote downward when
a tier fills.  Reads search top-down and (optionally) promote hits back to
MEM.  Per-tier hit/byte counters feed the benchmark for the paper's 30x
cached-read claim.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from collections import OrderedDict
from typing import Any, Optional

from repro.core import binpipe


@dataclasses.dataclass
class TierStats:
    hits: int = 0
    misses: int = 0
    bytes_read: int = 0
    bytes_written: int = 0


class _DirTier:
    """A directory-backed tier with optional artificial read latency."""

    def __init__(self, root: str, capacity: int, latency_s: float = 0.0,
                 bandwidth_bps: float = 0.0):
        self.root = root
        self.capacity = capacity
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps  # 0 = unmodelled (local disk speed)
        self.lru: OrderedDict[str, int] = OrderedDict()  # key -> size
        self.used = 0
        os.makedirs(root, exist_ok=True)
        # recover pre-existing blocks (restart path: persisted data must be
        # visible to a fresh process)
        for fname in sorted(os.listdir(root)):
            try:
                key = bytes.fromhex(fname).decode("utf-8")
            except ValueError:
                continue
            size = os.path.getsize(os.path.join(root, fname))
            self.lru[key] = size
            self.used += size

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key.encode("utf-8").hex())

    def _transfer_delay(self, nbytes: int) -> None:
        d = self.latency_s + (nbytes / self.bandwidth_bps if self.bandwidth_bps else 0.0)
        if d:
            time.sleep(d)

    def put(self, key: str, data: bytes) -> None:
        self._transfer_delay(len(data))
        path = self._path(key)
        with open(path, "wb") as f:
            f.write(data)
        if key in self.lru:
            self.used -= self.lru.pop(key)
        self.lru[key] = len(data)
        self.used += len(data)

    def get(self, key: str) -> Optional[bytes]:
        if key not in self.lru:
            return None
        self._transfer_delay(self.lru[key])
        with open(self._path(key), "rb") as f:
            data = f.read()
        self.lru.move_to_end(key)
        return data

    def delete(self, key: str) -> None:
        if key in self.lru:
            self.used -= self.lru.pop(key)
            try:
                os.remove(self._path(key))
            except FileNotFoundError:
                pass

    def evict_lru(self) -> Optional[tuple[str, bytes]]:
        if not self.lru:
            return None
        key, _ = next(iter(self.lru.items()))
        data = self.get(key)
        self.delete(key)
        return (key, data) if data is not None else None

    def keys(self):
        return list(self.lru)


class _MemTier:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self.data: OrderedDict[str, bytes] = OrderedDict()
        self.used = 0

    def put(self, key: str, data: bytes) -> None:
        if key in self.data:
            self.used -= len(self.data.pop(key))
        self.data[key] = data
        self.used += len(data)

    def get(self, key: str) -> Optional[bytes]:
        if key not in self.data:
            return None
        self.data.move_to_end(key)
        return self.data[key]

    def delete(self, key: str) -> None:
        if key in self.data:
            self.used -= len(self.data.pop(key))

    def evict_lru(self) -> Optional[tuple[str, bytes]]:
        if not self.data:
            return None
        key, data = self.data.popitem(last=False)
        self.used -= len(data)
        return key, data

    def keys(self):
        return list(self.data)


class TieredStore:
    """Alluxio-style tiered store with async persistence."""

    TIERS = ("MEM", "SSD", "HDD")

    def __init__(
        self,
        root: str,
        mem_capacity: int = 1 << 30,
        ssd_capacity: int = 8 << 30,
        hdd_capacity: int = 64 << 30,
        hdd_latency_s: float = 0.0,
        persist_latency_s: float = 0.0,
        persist_bandwidth_bps: float = 0.0,
        async_persist: bool = True,
        promote_on_read: bool = True,
    ):
        self.root = root
        self.tiers: dict[str, Any] = {
            "MEM": _MemTier(mem_capacity),
            "SSD": _DirTier(os.path.join(root, "ssd"), ssd_capacity),
            "HDD": _DirTier(os.path.join(root, "hdd"), hdd_capacity, hdd_latency_s),
        }
        self.persist = _DirTier(
            os.path.join(root, "persist"), 1 << 62, persist_latency_s,
            persist_bandwidth_bps,
        )
        self.stats = {t: TierStats() for t in (*self.TIERS, "PERSIST")}
        self.promote_on_read = promote_on_read
        self._lock = threading.RLock()
        self._persist_queue: "queue.Queue[Optional[tuple[str, bytes]]]" = queue.Queue()
        self._async = async_persist
        self._persist_errors: list[str] = []
        if async_persist:
            self._writer = threading.Thread(target=self._persist_loop, daemon=True)
            self._writer.start()

    # ------------------------------------------------------------------
    def _persist_loop(self):
        while True:
            item = self._persist_queue.get()
            if item is None:
                self._persist_queue.task_done()
                return
            key, data = item
            try:
                self.persist.put(key, data)
                self.stats["PERSIST"].bytes_written += len(data)
            except Exception as e:  # pragma: no cover
                self._persist_errors.append(f"{key}: {e}")
            finally:
                self._persist_queue.task_done()

    def _demote(self, tier_idx: int, key: str, data: bytes) -> None:
        """Place data in tier `tier_idx`, demoting LRU blocks as needed."""
        if tier_idx >= len(self.TIERS):
            return  # fell off the bottom; persist copy (already queued) remains
        tier = self.tiers[self.TIERS[tier_idx]]
        while tier.used + len(data) > tier.capacity and tier.keys():
            evicted = tier.evict_lru()
            if evicted is None:
                break
            self._demote(tier_idx + 1, *evicted)
        if len(data) <= tier.capacity:
            tier.put(key, data)
            self.stats[self.TIERS[tier_idx]].bytes_written += len(data)
        else:
            self._demote(tier_idx + 1, key, data)

    # ------------------------------------------------------------------
    def put(self, key: str, data: bytes, persist: bool = True) -> None:
        with self._lock:
            for t in self.TIERS:  # drop stale copies in lower tiers
                self.tiers[t].delete(key)
            self._demote(0, key, data)
            if persist:
                if self._async:
                    self._persist_queue.put((key, data))
                else:
                    self.persist.put(key, data)
                    self.stats["PERSIST"].bytes_written += len(data)

    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            for i, t in enumerate(self.TIERS):
                data = self.tiers[t].get(key)
                if data is not None:
                    self.stats[t].hits += 1
                    self.stats[t].bytes_read += len(data)
                    if self.promote_on_read and i > 0:
                        self.tiers[t].delete(key)
                        self._demote(0, key, data)
                    return data
                self.stats[t].misses += 1
            data = self.persist.get(key)
            if data is not None:
                self.stats["PERSIST"].hits += 1
                self.stats["PERSIST"].bytes_read += len(data)
                if self.promote_on_read:
                    self._demote(0, key, data)
                return data
            self.stats["PERSIST"].misses += 1
            return None

    def delete(self, key: str) -> None:
        with self._lock:
            for t in self.TIERS:
                self.tiers[t].delete(key)
            self.persist.delete(key)

    def exists(self, key: str) -> bool:
        with self._lock:
            return any(key in self.tiers[t].keys() for t in self.TIERS) or key in self.persist.keys()

    def flush(self) -> None:
        """Block until all queued persist writes are durable."""
        if self._async:
            self._persist_queue.join()
        if self._persist_errors:
            errs = "; ".join(self._persist_errors)
            self._persist_errors.clear()
            raise IOError(f"async persist failures: {errs}")

    def close(self) -> None:
        if self._async:
            self._persist_queue.put(None)
            self._writer.join(timeout=10)
            self._async = False

    def keys(self) -> list[str]:
        """Sorted union of keys across every cache tier and the persist
        store — the listing surface directory-style consumers (e.g. the
        campaign ``ArtifactStore``'s version index) need.  Blocks still in
        the async persist queue are covered by their cache-tier copy."""
        with self._lock:
            ks: set[str] = set()
            for t in self.TIERS:
                ks.update(self.tiers[t].keys())
            ks.update(self.persist.keys())
            return sorted(ks)

    def drop_caches(self) -> None:
        """Simulate losing every cache tier (node restart); persist survives."""
        with self._lock:
            for t in self.TIERS:
                for k in self.tiers[t].keys():
                    self.tiers[t].delete(k)

    # ------------------------------------------------------------------
    # typed helpers (records / numpy trees via the BinPipe codec)
    def put_record(self, key: str, record: dict, persist: bool = True) -> None:
        self.put(key, binpipe.encode_record(record), persist=persist)

    def get_record(self, key: str) -> Optional[dict]:
        data = self.get(key)
        return None if data is None else binpipe.decode_record(data)
