"""Parameter server on the tiered store (paper §4.2) + its TPU-native
replacement.

The paper stored model parameters in Alluxio so every Paddle trainer could
pull/push at memory speed (5x over HDFS-backed parameters).  Two embodiments
here:

* :class:`TieredParamServer` — a literal PS: versioned parameter pytrees
  stored in the :class:`TieredStore` MEM tier with async persistence.  Used
  by the host-side elastic/async training mode and the PS benchmark; pulls
  hit memory, durability is asynchronous, exactly the paper's deployment.

* ZeRO-1 sharded optimizer state (see ``training/optimizer.py``) — on a TPU
  torus, the performant "parameter server" is the collective permute ring:
  optimizer state lives sharded in the workers' HBM (memory tier!) and the
  per-step reduce-scatter/all-gather is the pull/push.  DESIGN.md §2 records
  this assumption change.
"""

from __future__ import annotations

import io
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.tiered_store import TieredStore


def _tree_to_bytes(tree: Any) -> bytes:
    leaves, treedef = jax.tree.flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, **{f"a{i}": np.asarray(x) for i, x in enumerate(leaves)})
    return buf.getvalue()


def _tree_from_bytes(data: bytes, like: Any) -> Any:
    _, treedef = jax.tree.flatten(like)
    loaded = np.load(io.BytesIO(data))
    return jax.tree.unflatten(treedef, [loaded[f"a{i}"] for i in range(len(loaded.files))])


class TieredParamServer:
    """Versioned pytree store with optimistic concurrency for async workers."""

    def __init__(self, store: TieredStore, name: str = "ps"):
        self.store = store
        self.name = name
        self._lock = threading.Lock()
        self.version = 0
        self._template: Any = None

    # ------------------------------------------------------------------
    def publish(self, params: Any) -> int:
        """Push a new parameter version (driver or reducer role)."""
        with self._lock:
            self.version += 1
            self._template = jax.tree.map(lambda x: np.asarray(x), params)
            self.store.put(f"{self.name}_v{self.version}", _tree_to_bytes(params))
            self.store.put(f"{self.name}_latest", str(self.version).encode())
            return self.version

    def pull(self) -> tuple[Any, int]:
        """Fetch the latest parameters (worker role)."""
        with self._lock:
            raw = self.store.get(f"{self.name}_latest")
            if raw is None:
                raise KeyError("no published parameters")
            v = int(raw.decode())
            data = self.store.get(f"{self.name}_v{v}")
            return _tree_from_bytes(data, self._template), v

    # ------------------------------------------------------------------
    def push_update(self, grads: Any, worker: str, version: int) -> None:
        """Workers push gradient contributions tagged with the version they
        computed against (staleness is visible to the reducer)."""
        key = f"{self.name}_grad_{worker}_v{version}"
        self.store.put(key, _tree_to_bytes(grads), persist=False)

    def gather_updates(self, workers: list[str], version: int) -> list[Any]:
        out = []
        for w in workers:
            data = self.store.get(f"{self.name}_grad_{w}_v{version}")
            if data is not None:
                out.append(_tree_from_bytes(data, self._template))
        return out

    def apply_mean_update(self, params: Any, updates: list[Any], lr: float) -> Any:
        """SGD-style reducer: params -= lr * mean(updates)."""
        if not updates:
            return params
        mean = jax.tree.map(lambda *gs: np.mean(np.stack(gs), axis=0), *updates)
        return jax.tree.map(lambda p, g: np.asarray(p) - lr * g, params, mean)
