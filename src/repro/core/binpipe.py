"""BinPipeRDD codec (paper §3.1).

The paper's BinPipeRDD lets Spark consume *binary* multimedia/sensor records
instead of line-oriented text: every supported input (strings, ints, binary
blobs, tensors) is encoded into a uniform byte-array format, byte arrays are
serialized into one stream per partition, and the user program deserializes,
computes, and re-encodes its outputs.

This module is that codec: a length-prefixed, typed, self-describing binary
record format used by the data pipeline (sensor logs, ROS-bag-style replay
data, tokenized LM shards) on the host side, plus batch helpers that stack
decoded records into device-ready numpy arrays.

Wire format (little-endian):
  stream  := MAGIC u32 | count u32 | (record_len u64 | record_bytes)*
  record  := nfields u16 | field*
  field   := name_len u16 | name utf8 | tag u8 | payload_len u64 | payload
  tags    : 0 bytes, 1 str, 2 i64, 3 f64, 4 ndarray (dtype_len u8 | dtype utf8
            | ndim u8 | dims i64* | raw buffer)
"""

from __future__ import annotations

import io
import struct
from typing import Any, Iterable

import numpy as np

MAGIC = 0xB1AE5EED

_TAG_BYTES, _TAG_STR, _TAG_INT, _TAG_FLOAT, _TAG_NDARRAY = range(5)


class BinPipeError(ValueError):
    pass


def _write_field(buf: io.BytesIO, name: str, value: Any) -> None:
    nb = name.encode("utf-8")
    buf.write(struct.pack("<H", len(nb)))
    buf.write(nb)
    if isinstance(value, (bytes, bytearray)):
        buf.write(struct.pack("<BQ", _TAG_BYTES, len(value)))
        buf.write(bytes(value))
    elif isinstance(value, str):
        vb = value.encode("utf-8")
        buf.write(struct.pack("<BQ", _TAG_STR, len(vb)))
        buf.write(vb)
    elif isinstance(value, (bool, np.bool_)):
        raise BinPipeError("bool fields not supported; use int")
    elif isinstance(value, (int, np.integer)):
        buf.write(struct.pack("<BQ", _TAG_INT, 8))
        buf.write(struct.pack("<q", int(value)))
    elif isinstance(value, (float, np.floating)):
        buf.write(struct.pack("<BQ", _TAG_FLOAT, 8))
        buf.write(struct.pack("<d", float(value)))
    elif isinstance(value, np.ndarray):
        arr = np.ascontiguousarray(value)
        db = arr.dtype.str.encode("ascii")
        header = struct.pack("<B", len(db)) + db + struct.pack("<B", arr.ndim)
        header += struct.pack(f"<{arr.ndim}q", *arr.shape)
        raw = arr.tobytes()
        buf.write(struct.pack("<BQ", _TAG_NDARRAY, len(header) + len(raw)))
        buf.write(header)
        buf.write(raw)
    else:
        raise BinPipeError(f"unsupported field type {type(value)} for {name!r}")


def encode_record(record: dict[str, Any]) -> bytes:
    """Encode one record (dict of supported values) to bytes."""
    buf = io.BytesIO()
    buf.write(struct.pack("<H", len(record)))
    for name, value in record.items():
        _write_field(buf, name, value)
    return buf.getvalue()


def decode_record(data: bytes) -> dict[str, Any]:
    buf = io.BytesIO(data)

    def read(fmt):
        size = struct.calcsize(fmt)
        raw = buf.read(size)
        if len(raw) != size:
            raise BinPipeError("truncated record")
        return struct.unpack(fmt, raw)

    (nfields,) = read("<H")
    out: dict[str, Any] = {}
    for _ in range(nfields):
        (name_len,) = read("<H")
        name = buf.read(name_len).decode("utf-8")
        tag, payload_len = read("<BQ")
        payload = buf.read(payload_len)
        if len(payload) != payload_len:
            raise BinPipeError("truncated payload")
        if tag == _TAG_BYTES:
            out[name] = payload
        elif tag == _TAG_STR:
            out[name] = payload.decode("utf-8")
        elif tag == _TAG_INT:
            out[name] = struct.unpack("<q", payload)[0]
        elif tag == _TAG_FLOAT:
            out[name] = struct.unpack("<d", payload)[0]
        elif tag == _TAG_NDARRAY:
            p = io.BytesIO(payload)
            (dlen,) = struct.unpack("<B", p.read(1))
            dtype = np.dtype(p.read(dlen).decode("ascii"))
            (ndim,) = struct.unpack("<B", p.read(1))
            shape = struct.unpack(f"<{ndim}q", p.read(8 * ndim)) if ndim else ()
            arr = np.frombuffer(p.read(), dtype=dtype)
            out[name] = arr.reshape(shape).copy()
        else:
            raise BinPipeError(f"unknown tag {tag}")
    return out


def serialize_stream(records: Iterable[bytes]) -> bytes:
    """Combine encoded records into a single partition byte stream."""
    records = list(records)
    buf = io.BytesIO()
    buf.write(struct.pack("<II", MAGIC, len(records)))
    for r in records:
        buf.write(struct.pack("<Q", len(r)))
        buf.write(r)
    return buf.getvalue()


def deserialize_stream(stream: bytes) -> list[bytes]:
    buf = io.BytesIO(stream)
    magic, count = struct.unpack("<II", buf.read(8))
    if magic != MAGIC:
        raise BinPipeError(f"bad magic {magic:#x}")
    out = []
    for _ in range(count):
        (n,) = struct.unpack("<Q", buf.read(8))
        rec = buf.read(n)
        if len(rec) != n:
            raise BinPipeError("truncated stream")
        out.append(rec)
    return out


def encode_partition(records: Iterable[dict[str, Any]]) -> bytes:
    return serialize_stream(encode_record(r) for r in records)


def decode_partition(stream: bytes) -> list[dict[str, Any]]:
    return [decode_record(r) for r in deserialize_stream(stream)]


def stack_batch(records: list[dict[str, Any]], fields: list[str] | None = None) -> dict[str, np.ndarray]:
    """Stack homogeneous ndarray/scalar fields across records into arrays."""
    if not records:
        return {}
    fields = fields or [
        k for k, v in records[0].items() if isinstance(v, (np.ndarray, int, float))
    ]
    out = {}
    for f in fields:
        vals = [r[f] for r in records]
        out[f] = np.stack([np.asarray(v) for v in vals])
    return out
