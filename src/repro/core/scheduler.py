"""Resource manager — the YARN + LXC role (paper §2.3).

The cluster's device pool is sliced into *containers* (contiguous sub-meshes)
that jobs are scheduled onto.  The paper used YARN for queueing/allocation
and LXC for isolation; on a TPU pod the isolation boundary is the sub-mesh
(a job only sees its own devices), and this module is the queue + allocator +
elasticity logic:

* FIFO-with-priority job queue over a shared device pool,
* allocation of power-of-two device blocks (sub-mesh "containers"),
* preemption of lower-priority jobs when a higher-priority job can't fit,
* elastic resize: a job may shrink to its ``min_devices`` under pressure and
  grow back when the pool frees up,
* failure handling: a dead container's devices are quarantined and the job
  is resubmitted (to be resumed from its checkpoint by the training driver),
* speculative re-execution of straggler partitions (Spark-style backup
  tasks) at the data-partition level.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import threading
import time
from typing import Callable, Optional


def _locked(fn):
    """Run a ResourceManager method under the pool lock."""

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._lock:
            return fn(self, *args, **kwargs)

    return wrapper


def _locked_notify(fn):
    """Run under the pool lock, then wake registered listeners *after* the
    lock is released.  Listeners (e.g. a Platform condition) may take their
    own locks; notifying outside the pool lock keeps the global lock order
    acyclic (platform -> ResourceManager, never the reverse)."""

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._lock:
            out = fn(self, *args, **kwargs)
        self._notify_listeners()
        return out

    return wrapper

JOB_PENDING = "PENDING"
JOB_RUNNING = "RUNNING"
JOB_PREEMPTED = "PREEMPTED"
JOB_FAILED = "FAILED"
JOB_DONE = "DONE"


@dataclasses.dataclass
class Job:
    name: str
    kind: str  # train | simulate | scenario | mapgen | serve (validated
    #            against the driver registry by repro.platform at submit)
    devices: int  # desired container size (power of two)
    min_devices: int = 1
    priority: int = 0  # higher wins
    state: str = JOB_PENDING
    container: Optional["Container"] = None
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    preemptions: int = 0
    resumes: int = 0
    resizes: int = 0  # accepted mid-run ResizeOffers (grow or shrink)
    # retry-backoff hold: schedule() skips the job until this monotonic
    # timestamp (0 = no hold); set by fail_container(delay_s=...) so a
    # flapping container can't thrash the queue with immediate retries
    not_before: float = 0.0


@dataclasses.dataclass
class Container:
    """An isolated slice of the device pool (the LXC analog)."""

    cid: int
    device_ids: tuple[int, ...]
    job: Optional[str] = None

    @property
    def size(self) -> int:
        return len(self.device_ids)


class ResourceManager:
    def __init__(self, total_devices: int):
        self.total = total_devices
        self.free: set[int] = set(range(total_devices))
        self.quarantined: set[int] = set()
        # device id -> monotonic timestamp of its quarantine, for healing
        # probes (heal_expired); healed/never-quarantined ids are absent
        self.quarantined_at: dict[int, float] = {}
        self.containers: dict[int, Container] = {}
        self.jobs: dict[str, Job] = {}
        self._cid = itertools.count(1)
        self.events: list[str] = []
        # one pool, many tenants: submit/complete may race from worker
        # threads (e.g. a sweep runner waiting out a train job); RLock
        # because complete() -> schedule() re-enters
        self._lock = threading.RLock()
        # completion/reschedule listeners: executors register a callback so
        # a foreign tenant's complete() wakes their wait loop instead of the
        # loop polling job states on a timer
        self._listeners: list[Callable[[], None]] = []

    # ------------------------------------------------------------------
    def _log(self, msg: str) -> None:
        self.events.append(msg)

    def add_listener(self, fn: Callable[[], None]) -> None:
        """Register a callback fired after any pool-state mutation (submit /
        complete / failure / heal / resize).  Called *outside* the pool lock;
        implementations must be cheap and non-reentrant into this manager."""
        self._listeners.append(fn)

    def remove_listener(self, fn: Callable[[], None]) -> None:
        try:
            self._listeners.remove(fn)
        except ValueError:
            pass

    def _notify_listeners(self) -> None:
        for fn in list(self._listeners):
            fn()

    @_locked_notify
    def submit(self, job: Job) -> str:
        if job.name in self.jobs:
            # multi-tenant pool: callers race on friendly names, so rename
            # instead of rejecting (the final name is the handle)
            base, i = job.name, 2
            while f"{base}-{i}" in self.jobs:
                i += 1
            job.name = f"{base}-{i}"
            self._log(f"uniquified duplicate job name {base} -> {job.name}")
        self.jobs[job.name] = job
        self._log(f"submit {job.name} kind={job.kind} want={job.devices}")
        self.schedule()
        return job.name

    @staticmethod
    def _runs(ids: set[int]) -> list[tuple[int, int]]:
        """Maximal contiguous runs of device ids as (start, length)."""
        runs = []
        start = prev = None
        for d in sorted(ids):
            if prev is None or d != prev + 1:
                if start is not None:
                    runs.append((start, prev - start + 1))
                start = d
            prev = d
        if start is not None:
            runs.append((start, prev - start + 1))
        return runs

    @classmethod
    def _max_run(cls, ids: set[int]) -> int:
        return max((length for _, length in cls._runs(ids)), default=0)

    @_locked
    def free_runs(self) -> list[tuple[int, int]]:
        """Maximal contiguous free-device runs as (start, length) — the pool
        shape signal elasticity decisions (grow offers, ``--shards auto``)
        are derived from."""
        return self._runs(self.free)

    def _allocate(self, n: int) -> Optional[Container]:
        """Claim a *contiguous* block of n devices (the sub-mesh container
        promise) — best-fit over free runs so preemption churn doesn't
        fragment the pool."""
        if n <= 0 or len(self.free) < n:
            return None
        fits = [(length, start) for start, length in self._runs(self.free) if length >= n]
        if not fits:
            return None
        _, start = min(fits)
        ids = tuple(range(start, start + n))
        self.free.difference_update(ids)
        c = Container(next(self._cid), ids)
        self.containers[c.cid] = c
        return c

    def _allocate_shrinking(self, size: int, min_devices: int) -> Optional[Container]:
        """Try ``size`` first, halving toward ``min_devices`` when
        fragmentation leaves no contiguous run that large."""
        c = None
        while c is None and size >= min_devices:
            c = self._allocate(size)
            if c is None:
                if size == min_devices:
                    break
                size = max(size // 2, min_devices)
        return c

    def _release(self, c: Container) -> None:
        self.free.update(set(c.device_ids) - self.quarantined)
        self.containers.pop(c.cid, None)

    # ------------------------------------------------------------------
    @_locked
    def schedule(self) -> None:
        """Greedy highest-priority-first packing with shrink + preemption.
        Jobs under a retry-backoff hold (``not_before`` in the future) are
        skipped; ``kick_expired`` reschedules them when the hold lapses."""
        now = time.monotonic()
        pending = sorted(
            (j for j in self.jobs.values()
             if j.state in (JOB_PENDING, JOB_PREEMPTED)
             and j.not_before <= now),
            key=lambda j: (-j.priority, j.submitted_at),
        )
        for job in pending:
            size = job.devices
            c = self._allocate(size)
            if c is None and len(self.free) >= job.min_devices:
                # elastic shrink: take what's available (>= min)
                size = 1 << (len(self.free).bit_length() - 1)
                size = max(size, job.min_devices)
                c = self._allocate_shrinking(size, job.min_devices)
                if c is not None:
                    self._log(f"shrink {job.name} -> {c.size}")
            if c is None:
                c = self._preempt_for(job)
            if c is None:
                continue
            c.job = job.name
            job.container = c
            if job.state == JOB_PREEMPTED:
                job.resumes += 1
            job.state = JOB_RUNNING
            self._log(f"run {job.name} on container {c.cid} ({c.size} devices)")

    def _preempt_for(self, job: Job) -> Optional[Container]:
        victims = sorted(
            (j for j in self.jobs.values() if j.state == JOB_RUNNING and j.priority < job.priority),
            key=lambda j: j.priority,
        )
        # dry-run the evictions: only preempt if the resulting free pool has a
        # *contiguous* run big enough — otherwise victims would lose progress
        # for an allocation that still fails on fragmentation
        hypo = set(self.free)
        taken = []
        for v in victims:
            hypo.update(set(v.container.device_ids) - self.quarantined)
            taken.append(v)
            if self._max_run(hypo) >= job.min_devices:
                break
        fits = [(length, start) for start, length in self._runs(hypo)
                if length >= job.min_devices]
        if not fits:
            return None
        # spare victims whose devices don't touch the winning run — evicting
        # them would cost their progress without helping the requester
        length, start = min(fits)
        run_ids = set(range(start, start + length))
        taken = [v for v in taken if set(v.container.device_ids) & run_ids]
        for v in taken:
            self._log(f"preempt {v.name}")
            self._release(v.container)
            v.container = None
            v.state = JOB_PREEMPTED
            v.preemptions += 1
        want = min(job.devices, len(self.free))
        size = 1 << (want.bit_length() - 1) if want else 0
        size = max(size, job.min_devices)
        return self._allocate_shrinking(size, job.min_devices)

    # ------------------------------------------------------------------
    @_locked_notify
    def resize(self, name: str, devices: int) -> Optional[Container]:
        """Re-grant a RUNNING job's container at a new size — the commit half
        of an accepted ResizeOffer.  The old container is released first (so
        a grow can absorb the adjacent free run), then a fresh contiguous
        block of ``devices`` (clamped to [min_devices, job.devices]) is
        claimed, shrinking toward ``min_devices`` if the pool fragmented in
        between.  Returns the new container, or None when the job was not
        resizable (not RUNNING) or nothing could be granted — in which case
        the job is requeued PENDING at its desired size.

        Freed devices are offered to the queue immediately, which is the
        whole point of a shrink offer: a queued tenant starts on them."""
        job = self.jobs[name]
        if job.state != JOB_RUNNING or job.container is None:
            return None
        devices = max(job.min_devices, min(devices, job.devices))
        old = job.container
        if devices == old.size:
            return old
        self._release(old)
        job.container = None
        c = self._allocate_shrinking(devices, job.min_devices)
        if c is None:
            # the pool churned underneath the offer: requeue at desired size
            job.state = JOB_PENDING
            self._log(f"resize {name} -> {devices} failed; requeued")
            self.schedule()
            return None
        c.job = name
        job.container = c
        job.resizes += 1
        self._log(f"resize {name}: {old.size} -> {c.size} devices")
        self.schedule()  # a shrink's freed devices go to queued tenants now
        return c

    @_locked_notify
    def complete(self, name: str, state: str = JOB_DONE) -> None:
        """Terminate a job and free its container.  ``state`` records the
        outcome (JOB_DONE, or JOB_FAILED for driver errors) so co-tenants
        inspecting the shared pool see the real disposition."""
        job = self.jobs[name]
        job.state = state
        if job.container:
            self._release(job.container)
            job.container = None
        self._log(f"{'done' if state == JOB_DONE else state.lower()} {name}")
        self.schedule()

    @_locked
    def running_jobs(self, exclude=()) -> list[str]:
        """Names of RUNNING jobs not in ``exclude`` — how an executor spots
        foreign tenants holding the pool before declaring itself stuck."""
        return [
            j.name
            for j in self.jobs.values()
            if j.state == JOB_RUNNING and j.name not in exclude
        ]

    @_locked_notify
    def fail_container(
        self, name: str, dead_devices: int = 1, delay_s: float = 0.0
    ) -> None:
        """A node in the job's container died: quarantine devices, resubmit.

        ``dead_devices=0`` means the *worker* died but its devices are fine
        (e.g. a killed isolated process): nothing is quarantined, the job is
        just requeued.  ``delay_s > 0`` holds the requeued job out of
        ``schedule()`` until the backoff lapses (``Job.not_before``)."""
        job = self.jobs[name]
        if job.container is None:
            return
        dead = set(job.container.device_ids[:dead_devices])
        if dead:
            now = time.monotonic()
            self.quarantined.update(dead)
            self.quarantined_at.update({d: now for d in dead})
            self._log(f"container failure in {name}: quarantine {sorted(dead)}")
        else:
            self._log(f"container failure in {name}: worker lost, devices kept")
        self._release(job.container)
        job.container = None
        job.state = JOB_PENDING  # driver resumes from checkpoint on reschedule
        job.not_before = time.monotonic() + delay_s if delay_s > 0 else 0.0
        self.schedule()

    @_locked_notify
    def quarantine_devices(self, device_ids) -> None:
        """Mark devices dead without rescheduling their job — used when a
        failing job is abandoned (e.g. retries exhausted) but its devices
        must still be kept out of the pool."""
        dead = set(device_ids)
        if not dead:
            return
        now = time.monotonic()
        self.quarantined.update(dead)
        self.quarantined_at.update({d: now for d in dead})
        self.free.difference_update(dead)
        self._log(f"quarantine {sorted(dead)}")

    @_locked_notify
    def heal(self, device_ids: Optional[list[int]] = None) -> None:
        ids = set(device_ids) if device_ids else set(self.quarantined)
        self.quarantined.difference_update(ids)
        for d in ids:
            self.quarantined_at.pop(d, None)
        self.free.update(ids)
        self.schedule()

    def heal_expired(self, after_s: float, now: Optional[float] = None) -> list[int]:
        """Healing probe: devices quarantined at least ``after_s`` ago are
        probed (trivially healthy in this repro — real pools would run a
        device self-test) and returned to the pool.  Returns the healed ids.
        """
        with self._lock:
            t = time.monotonic() if now is None else now
            due = sorted(
                d for d, at in self.quarantined_at.items()
                if d in self.quarantined and t - at >= after_s
            )
            for d in due:
                self._log(f"healing probe passed: device {d} rejoins the pool")
        if due:
            self.heal(due)  # reschedules + notifies listeners
        return due

    def kick_expired(self) -> list[str]:
        """Re-run the scheduler for jobs whose retry-backoff hold has lapsed;
        returns the names whose hold was cleared.  Called from executor wait
        loops (cheap no-op while every hold is still ticking)."""
        kicked = []
        with self._lock:
            now = time.monotonic()
            for job in self.jobs.values():
                if job.not_before and job.not_before <= now \
                        and job.state in (JOB_PENDING, JOB_PREEMPTED):
                    job.not_before = 0.0
                    kicked.append(job.name)
            if kicked:
                self.schedule()
        if kicked:
            self._notify_listeners()
        return kicked

    @_locked
    def earliest_hold(self) -> Optional[float]:
        """The soonest ``not_before`` among held queued jobs (monotonic
        timestamp), or None — bounds the executor's condition-wait so a
        backoff retry fires on time."""
        now = time.monotonic()
        holds = [
            j.not_before for j in self.jobs.values()
            if j.not_before > now and j.state in (JOB_PENDING, JOB_PREEMPTED)
        ]
        return min(holds) if holds else None

    def utilization(self) -> float:
        busy = sum(c.size for c in self.containers.values())
        return busy / max(self.total, 1)


# ---------------------------------------------------------------------------
# Straggler mitigation: Spark-style speculative backup tasks
# ---------------------------------------------------------------------------


def run_with_speculation(
    task_fn: Callable[[int], object],
    partitions: list[int],
    runtimes: dict[int, float],
    speculation_multiple: float = 1.5,
) -> tuple[dict[int, object], list[int]]:
    """Execute `task_fn` per partition; partitions whose (simulated or
    measured) runtime exceeds ``speculation_multiple`` x median get a backup
    execution, and the fastest copy wins — Spark speculative execution, which
    is where straggler mitigation lives when the inner step is SPMD.

    ``runtimes`` carries observed/estimated per-partition runtimes; the
    return includes which partitions were speculatively re-executed.
    """
    times = sorted(runtimes.get(p, 1.0) for p in partitions)
    median = times[len(times) // 2] if times else 1.0
    results: dict[int, object] = {}
    speculated: list[int] = []
    for p in partitions:
        results[p] = task_fn(p)
        if runtimes.get(p, 1.0) > speculation_multiple * median:
            backup = task_fn(p)  # deterministic tasks: either copy is valid
            results[p] = backup
            speculated.append(p)
    return results, speculated
