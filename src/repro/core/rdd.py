"""ShardedDataset — the RDD abstraction (paper §2.1).

A read-only, partitioned dataset whose partitions are produced by a
deterministic *lineage*: either a seeded generator (source datasets) or a
transformation of a parent dataset.  Exactly Spark's fault-tolerance story:
when a cached partition is lost (node failure), it is **recomputed from
lineage** rather than restarting the job, and only the lost partition pays
the recomputation cost.

Partitions hold lists of BinPipe-codable records (dicts of
str/int/float/bytes/ndarray).  ``cache()`` pins encoded partitions into a
:class:`~repro.core.tiered_store.TieredStore`, which is the Alluxio
co-location from §2.2.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional

import numpy as np

from repro.core import binpipe
from repro.core.tiered_store import TieredStore

Record = dict[str, Any]


@dataclasses.dataclass
class _Lineage:
    kind: str  # source | map | map_partitions | filter | zip
    parents: tuple["ShardedDataset", ...]
    fn: Optional[Callable] = None
    desc: str = ""


class ShardedDataset:
    _ids = iter(range(1, 1 << 62))

    def __init__(self, num_partitions: int, lineage: _Lineage, name: str = ""):
        self.num_partitions = num_partitions
        self.lineage = lineage
        self.id = next(self._ids)
        self.name = name or f"rdd{self.id}"
        self._cache: Optional[TieredStore] = None
        self._materialized: dict[int, list[Record]] = {}
        self._lost: set[int] = set()
        self.recompute_count = 0  # lineage recoveries performed (observability)

    # ------------------------------------------------------------------
    # constructors
    @staticmethod
    def from_generator(
        gen: Callable[[int], Iterable[Record]], num_partitions: int, name: str = ""
    ) -> "ShardedDataset":
        """`gen(partition_index)` must be deterministic — it IS the lineage root."""
        return ShardedDataset(
            num_partitions, _Lineage("source", (), gen, "source"), name=name
        )

    @staticmethod
    def from_records(records: list[Record], num_partitions: int, name: str = "") -> "ShardedDataset":
        chunks = np.array_split(np.arange(len(records)), num_partitions)

        def gen(i: int):
            return [records[j] for j in chunks[i]]

        return ShardedDataset.from_generator(gen, num_partitions, name=name)

    # ------------------------------------------------------------------
    # transformations (lazy — record lineage only)
    def map(self, fn: Callable[[Record], Record], desc: str = "map") -> "ShardedDataset":
        return ShardedDataset(self.num_partitions, _Lineage("map", (self,), fn, desc))

    def map_partitions(
        self, fn: Callable[[list[Record]], list[Record]], desc: str = "map_partitions"
    ) -> "ShardedDataset":
        return ShardedDataset(self.num_partitions, _Lineage("map_partitions", (self,), fn, desc))

    def filter(self, pred: Callable[[Record], bool], desc: str = "filter") -> "ShardedDataset":
        return ShardedDataset(self.num_partitions, _Lineage("filter", (self,), pred, desc))

    def zip_partitions(
        self, other: "ShardedDataset", fn: Callable[[list[Record], list[Record]], list[Record]]
    ) -> "ShardedDataset":
        if other.num_partitions != self.num_partitions:
            raise ValueError("zip requires equal partitioning")
        return ShardedDataset(self.num_partitions, _Lineage("zip", (self, other), fn, "zip"))

    # ------------------------------------------------------------------
    # execution
    def _cache_key(self, idx: int) -> str:
        return f"rdd{self.id}_part{idx}"

    def compute_partition(self, idx: int) -> list[Record]:
        """Materialize partition `idx`, via cache when available, else lineage."""
        if idx >= self.num_partitions:
            raise IndexError(idx)
        if idx in self._lost:
            # simulate a failed node: local copy is gone; fall through to
            # cache/lineage below, counting the recovery
            self._materialized.pop(idx, None)
            self._lost.discard(idx)
            self.recompute_count += 1
        if idx in self._materialized:
            return self._materialized[idx]
        if self._cache is not None:
            blob = self._cache.get(self._cache_key(idx))
            if blob is not None:
                recs = binpipe.decode_partition(blob)
                self._materialized[idx] = recs
                return recs
        lg = self.lineage
        if lg.kind == "source":
            recs = list(lg.fn(idx))
        elif lg.kind == "map":
            recs = [lg.fn(r) for r in lg.parents[0].compute_partition(idx)]
        elif lg.kind == "map_partitions":
            recs = list(lg.fn(lg.parents[0].compute_partition(idx)))
        elif lg.kind == "filter":
            recs = [r for r in lg.parents[0].compute_partition(idx) if lg.fn(r)]
        elif lg.kind == "zip":
            recs = list(
                lg.fn(
                    lg.parents[0].compute_partition(idx),
                    lg.parents[1].compute_partition(idx),
                )
            )
        else:  # pragma: no cover
            raise ValueError(lg.kind)
        self._materialized[idx] = recs
        if self._cache is not None:
            self._cache.put(self._cache_key(idx), binpipe.encode_partition(recs))
        return recs

    def cache(self, store: TieredStore) -> "ShardedDataset":
        self._cache = store
        return self

    def collect(self) -> list[Record]:
        out: list[Record] = []
        for i in range(self.num_partitions):
            out.extend(self.compute_partition(i))
        return out

    def count(self) -> int:
        return sum(len(self.compute_partition(i)) for i in range(self.num_partitions))

    def aggregate(self, zero, seq_op, comb_op):
        """Spark-style treeAggregate over partitions (driver-side combine)."""
        acc = zero
        for i in range(self.num_partitions):
            part_acc = zero
            for r in self.compute_partition(i):
                part_acc = seq_op(part_acc, r)
            acc = comb_op(acc, part_acc)
        return acc

    # ------------------------------------------------------------------
    # failure injection / recovery (tests + scheduler integration)
    def lose_partition(self, idx: int) -> None:
        """Simulate the node holding partition `idx` dying."""
        self._lost.add(idx)
        if self._cache is not None:
            self._cache.delete(self._cache_key(idx))

    def lineage_depth(self) -> int:
        lg, d = self.lineage, 1
        while lg.parents:
            d += 1
            lg = lg.parents[0].lineage
        return d
