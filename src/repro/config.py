"""Configuration system for the repro framework.

Every job in the platform — a training run, a serving instance, a replay
simulation, a map-generation pipeline — is described by a small set of frozen
dataclasses.  Architecture configs (one per assigned architecture) live in
``repro.configs.*`` and are registered into :data:`ARCH_REGISTRY`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (GShard-style capacity routing)."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 1e-2
    router_z_coef: float = 1e-3
    # 'expert': shard the expert axis over the model mesh axis (needs E % tp == 0)
    # 'ffn'   : shard each expert's FFN dim over the model mesh axis
    shard_mode: str = "expert"
    # dispatch groups (GShard): sort/bin tokens within G batch groups so the
    # routing data movement stays local to the data shards.  0 = one global
    # group (cross-shard sort; the naive baseline).  16 aligns with the
    # production data axis.
    n_groups: int = 0
    # pad the expert axis (dead experts are never routed to) so it divides
    # the model mesh axis and shard_mode='expert' applies (e.g. 60 -> 64)
    pad_experts_to: int = 0

    @property
    def effective_experts(self) -> int:
        return max(self.num_experts, self.pad_experts_to)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    state_dim: int = 128
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single architecture.

    ``family`` selects the top-level model builder:
      dense | moe | ssm | hybrid | encdec | vlm
    (audio enc-dec uses family='encdec' with frontend='audio_frames';
    VLM uses family='vlm' with frontend='vision_patches').
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // num_heads
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"
    glu: bool = True  # gated MLP (SwiGLU / GeGLU)
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0
    rope_mode: str = "standard"  # standard | mrope | none
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    moe_every: int = 1  # layer i is MoE iff moe is set and i % moe_every == 0
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): one *shared* attention block invoked every N
    # backbone layers, with a per-site LoRA delta of this rank (0 = plain share)
    hybrid_attn_every: int = 0
    hybrid_lora_rank: int = 0

    # encoder/decoder split (family == 'encdec'); num_layers is the total.
    encoder_layers: int = 0
    decoder_layers: int = 0

    # modality frontend stub: none | vision_patches | audio_frames
    frontend: str = "none"
    frontend_tokens: int = 0  # patches / frames prepended per example
    frontend_dim: int = 0  # raw embedding dim supplied by the (stub) frontend

    max_seq_len: int = 32_768
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 2048

    # runtime knobs (overridable per run)
    remat: str = "dots"  # none | dots | full
    scan_layers: bool = True
    attention_impl: str = "einsum"  # einsum (GSPMD path) | blocked | flash | hd_sharded
    attn_scores_bf16: bool = False  # halve attention-score traffic (flagged numerics)

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family == "moe" and self.moe is None:
            raise ValueError("family='moe' requires moe config")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"family={self.family!r} requires ssm config")
        if self.family == "encdec" and not (self.encoder_layers and self.decoder_layers):
            raise ValueError("encdec requires encoder_layers and decoder_layers")

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm" or self.hybrid_attn_every > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch has a linear-cost sequence-mixing path (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (unpadded vocab), for 6ND roofline math."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        v = self.vocab_size

        def attn_params() -> int:
            p = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
            if self.qkv_bias:
                p += (nq + 2 * nkv) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def dense_mlp_params(dff: int) -> int:
            mult = 3 if self.glu else 2
            p = mult * d * dff
            if self.mlp_bias:
                p += (mult - 1) * dff + d
            return p

        def ssm_params() -> int:
            assert self.ssm is not None
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.state_dim
            p = d * (2 * d_in + 2 * s.n_groups * s.state_dim + nheads)  # in_proj
            p += conv_dim * s.conv_width  # depthwise conv
            p += nheads * 3  # A_log, D, dt_bias
            p += d_in  # gate norm
            p += d_in * d  # out_proj
            return p

        norms = 2 * d  # per layer (pre-attn + pre-mlp), rms weights only
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head

        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + dense_mlp_params(self.d_ff) + norms
            total += self.num_layers * per_layer
        elif self.family == "moe":
            assert self.moe is not None
            m = self.moe
            expert = dense_mlp_params(m.expert_d_ff)
            shared = dense_mlp_params(m.shared_d_ff) if m.num_shared_experts else 0
            router = d * m.num_experts
            per_layer = attn_params() + m.num_experts * expert + shared + router + norms
            total += self.num_layers * per_layer
        elif self.family == "ssm":
            total += self.num_layers * (ssm_params() + d)
        elif self.family == "hybrid":
            backbone = self.num_layers * (ssm_params() + d)
            shared_block = attn_params() + dense_mlp_params(self.d_ff) + norms
            n_sites = self.num_layers // max(self.hybrid_attn_every, 1)
            lora = 0
            if self.hybrid_lora_rank:
                r = self.hybrid_lora_rank
                lora = n_sites * 3 * (2 * d * r)  # q,k,v lora pairs per site
            total += backbone + shared_block + lora
        elif self.family == "encdec":
            enc_layer = attn_params() + dense_mlp_params(self.d_ff) + norms
            dec_layer = 2 * attn_params() + dense_mlp_params(self.d_ff) + 3 * d
            total += self.encoder_layers * enc_layer + self.decoder_layers * dec_layer
        if self.frontend != "none" and self.frontend_dim:
            total += self.frontend_dim * d  # frontend projection stub
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (== param_count except MoE top-k routing)."""
        if self.family != "moe":
            return self.param_count()
        assert self.moe is not None
        m = self.moe
        d = self.d_model

        def dense_mlp_params(dff: int) -> int:
            return (3 if self.glu else 2) * d * dff

        inactive_per_layer = (m.num_experts - m.top_k) * dense_mlp_params(m.expert_d_ff)
        return int(self.param_count() - self.num_layers * inactive_per_layer)


# ---------------------------------------------------------------------------
# Shapes (the assigned input-shape set)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Skip rules from the brief.

    * ``long_500k`` needs a sub-quadratic sequence path -> SSM/hybrid only.
    * decode shapes need a decoder (all archs in the pool have one).
    """
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (no sub-quadratic path)"
    return True, ""


# ---------------------------------------------------------------------------
# Mesh / parallelism
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Logical mesh. ``pod`` is the cross-pod (DCN) axis; data/model are ICI."""

    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def num_devices(self) -> int:
        return self.pod * self.data * self.model

    @property
    def axis_names(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.pod, self.data, self.model) if self.pod > 1 else (self.data, self.model)

    @property
    def dp_axes(self) -> tuple[str, ...]:
        """Axes used for batch (data) parallelism."""
        return ("pod", "data") if self.pod > 1 else ("data",)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Sharding strategy knobs (resolved against a MeshConfig per arch)."""

    zero1: bool = True  # shard optimizer state over the data axes
    weights_2d: bool = False  # also shard weight d_model dim over 'data' (ZeRO-3-ish)
    seq_shard_prefill: bool = False  # context parallelism for long prefill
    grad_compression: str = "none"  # none | int8
    hierarchical_allreduce: bool = True  # pod-aware reduce for multi-pod
    num_microbatches: int = 1
    pipeline_stages: int = 1  # >1 enables the optional GPipe axis (tests only)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    optimizer: str = "adamw"  # adamw | adafactor | sgd
    z_loss_coef: float = 1e-4
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

ARCH_REGISTRY: dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in ARCH_REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name!r}")
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    # importing repro.configs populates the registry lazily
    if not ARCH_REGISTRY:
        import repro.configs  # noqa: F401
    if name not in ARCH_REGISTRY:
        import repro.configs  # noqa: F401
    try:
        return ARCH_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_REGISTRY)}") from None


def list_archs() -> list[str]:
    if not ARCH_REGISTRY:
        import repro.configs  # noqa: F401
    return sorted(ARCH_REGISTRY)


def scale_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Produce a reduced config of the same family for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        vocab_pad_multiple=64,
        max_seq_len=512,
        dtype="float32",
        scan_layers=cfg.scan_layers,
        frontend_tokens=min(cfg.frontend_tokens, 8) if cfg.frontend_tokens else 0,
        frontend_dim=64 if cfg.frontend_dim else 0,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64,
            shared_d_ff=64 if cfg.moe.num_shared_experts else 0,
            # ample capacity: smoke tests check decode == full forward, which
            # only holds exactly when no token is capacity-dropped
            capacity_factor=8.0,
        )
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk_size=64
        )
    if cfg.family == "encdec":
        small["encoder_layers"] = min(cfg.encoder_layers, 2)
        small["decoder_layers"] = min(cfg.decoder_layers, 2)
        small["num_layers"] = small["encoder_layers"] + small["decoder_layers"]
    if cfg.hybrid_attn_every:
        small["hybrid_attn_every"] = 2
    small.update(overrides)
    small["name"] = cfg.name + "-smoke"
    return dataclasses.replace(cfg, **small)
