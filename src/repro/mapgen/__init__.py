"""HD map generation service (paper §5)."""

from repro.mapgen.pipeline import MapGenPipeline  # noqa: F401
