"""Pose estimation for map generation (paper §5.2).

"First, the wheel odometry data and the IMU data can be used to perform
propagation ... then the GPS data and the LiDAR data can be used to correct
the propagation results."

Implemented as a 2.5D (x, y, yaw) extended Kalman filter over the whole log,
fully in JAX (``lax.scan`` over time):

  propagate:  x' = x + v cos(yaw) dt,  y' = y + v sin(yaw) dt,
              yaw' = yaw + yaw_rate dt        (odometry v, IMU yaw_rate)
  correct:    GPS position update with per-fix gain.

LiDAR-based refinement (scan-to-scan ICP on the Pallas kernel) happens in
``pipeline.py`` on top of these poses.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EKFParams(NamedTuple):
    q_pos: float = 0.02  # process noise (position)
    q_yaw: float = 0.005
    r_gps: float = 0.5  # GPS measurement noise


def propagate_and_correct(
    odom_v: jax.Array,  # (T,) wheel-odometry speed
    imu_yaw_rate: jax.Array,  # (T,)
    gps: jax.Array,  # (T, 2) noisy position fixes
    dt: float = 0.1,
    init_pose: jax.Array | None = None,
    params: EKFParams = EKFParams(),
) -> jax.Array:
    """Returns poses (T, 3): x, y, yaw."""
    T = odom_v.shape[0]
    if init_pose is None:
        init_pose = jnp.concatenate([gps[0], jnp.array([jnp.pi / 2], gps.dtype)])

    P0 = jnp.diag(jnp.array([1.0, 1.0, 0.1], jnp.float32))
    Q = jnp.diag(jnp.array([params.q_pos, params.q_pos, params.q_yaw], jnp.float32))
    R = jnp.eye(2, dtype=jnp.float32) * params.r_gps
    H = jnp.array([[1.0, 0, 0], [0, 1.0, 0]], jnp.float32)

    def step(carry, inp):
        pose, P = carry
        v, w, z = inp
        x, y, yaw = pose
        # propagate
        pose_p = jnp.array([x + v * jnp.cos(yaw) * dt, y + v * jnp.sin(yaw) * dt, yaw + w * dt])
        F = jnp.array(
            [
                [1.0, 0.0, -v * jnp.sin(yaw) * dt],
                [0.0, 1.0, v * jnp.cos(yaw) * dt],
                [0.0, 0.0, 1.0],
            ],
            jnp.float32,
        )
        P_p = F @ P @ F.T + Q
        # GPS correction
        S = H @ P_p @ H.T + R
        K = P_p @ H.T @ jnp.linalg.inv(S)
        innov = z - pose_p[:2]
        pose_c = pose_p + K @ innov
        P_c = (jnp.eye(3) - K @ H) @ P_p
        return (pose_c, P_c), pose_c

    (_, _), poses = jax.lax.scan(
        step, (init_pose.astype(jnp.float32), P0), (odom_v, imu_yaw_rate, gps)
    )
    return poses


def pose_to_matrix(pose: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(x, y, yaw) -> (R (3,3), t (3,)) vehicle->world."""
    x, y, yaw = pose[0], pose[1], pose[2]
    c, s = jnp.cos(yaw), jnp.sin(yaw)
    R = jnp.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
    t = jnp.array([x, y, 0.0])
    return R, t


def transform_cloud(pose: jax.Array, cloud: jax.Array) -> jax.Array:
    """Vehicle-frame LiDAR points (N,3) -> world frame under (x,y,yaw)."""
    R, t = pose_to_matrix(pose)
    return cloud @ R.T + t
