"""The full HD-map-generation job (paper §5.2).

Stages, mirroring the paper's Figure 10:

  1. load        — decode BinPipe drive-log partitions, stack sensor arrays
  2. slam        — EKF propagation (odometry+IMU) corrected by GPS
  3. transform   — LiDAR scans vehicle->world under the SLAM poses
  4. icp_refine  — scan-to-scan ICP (Pallas kernel) refining consecutive
                   relative poses; the paper's 30x-offloaded hot spot
  5. rasterize   — 2D reflectance/elevation grid (segment scatter-reduce)
  6. label       — semantic layer on top of the grid

Stages 2-6 are jax-traceable, so the job runs either FUSED (one jit, the
paper's one-Spark-job 5x path) or STAGED (host round-trip per stage) through
``core.pipeline.Pipeline`` — benchmarked in ``benchmarks/mapgen.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binpipe import stack_batch
from repro.core.pipeline import Pipeline, Stage
from repro.core.rdd import ShardedDataset
from repro.kernels.icp.ops import icp_step
from repro.mapgen import gridmap, slam
from repro.mapgen.gridmap import GridMap, GridSpec


@dataclasses.dataclass
class MapGenConfig:
    grid: GridSpec = GridSpec(x_min=-40.0, y_min=-40.0, cells_x=160, cells_y=160, resolution=0.5)
    dt: float = 0.1
    icp_refine: bool = True
    use_pallas_icp: bool = True


class MapGenPipeline:
    def __init__(self, cfg: MapGenConfig = MapGenConfig()):
        self.cfg = cfg

    # ---- stage 1 (host): decode + stack ----
    def load(self, dataset: ShardedDataset) -> dict[str, jnp.ndarray]:
        recs = dataset.collect()
        batch = stack_batch(recs, ["lidar", "odom_v", "imu_yaw_rate", "gps", "pose_true"])
        return {
            "lidar": jnp.asarray(batch["lidar"]),  # (T, N, 3)
            "odom_v": jnp.asarray(batch["odom_v"], jnp.float32),
            "imu_yaw_rate": jnp.asarray(batch["imu_yaw_rate"], jnp.float32),
            "gps": jnp.asarray(batch["gps"], jnp.float32),
            "pose_true": jnp.asarray(batch["pose_true"], jnp.float32),
        }

    # ---- jax stages ----
    def stage_slam(self, data: dict) -> dict:
        poses = slam.propagate_and_correct(
            data["odom_v"], data["imu_yaw_rate"], data["gps"], dt=self.cfg.dt
        )
        return dict(data, poses=poses)

    def stage_transform(self, data: dict) -> dict:
        world = jax.vmap(slam.transform_cloud)(data["poses"], data["lidar"])
        return dict(data, world=world)

    def stage_icp_refine(self, data: dict) -> dict:
        """Scan-to-scan ICP between consecutive world-frame clouds; the
        residual transform corrects each pose's cloud.  (One ICP iteration
        per pair keeps the stage compile-light; iterations are configurable
        in the kernel op.)"""
        if not self.cfg.icp_refine:
            return dict(data, refined=data["world"], icp_err=jnp.zeros((1,)))
        clouds = data["world"]  # (T, N, 3)

        def refine(prev, cur):
            R, t, err = icp_step(cur, prev, interpret=None if self.cfg.use_pallas_icp else True)
            return cur @ R.T + t, err

        refined_tail, errs = jax.vmap(refine)(clouds[:-1], clouds[1:])
        refined = jnp.concatenate([clouds[:1], refined_tail], axis=0)
        return dict(data, refined=refined, icp_err=errs)

    def stage_rasterize(self, data: dict) -> dict:
        pts = data["refined"].reshape(-1, 3)
        # reflectance stub: deterministic per-point pseudo-intensity
        inten = (jnp.abs(jnp.sin(pts[:, 0] * 12.9898) * jnp.cos(pts[:, 1] * 78.233)))
        counts, elev, refl = gridmap.rasterize(pts, inten, self.cfg.grid)
        return dict(data, counts=counts, elevation=elev, reflectance=refl)

    def stage_label(self, data: dict) -> dict:
        labels = gridmap.label_map(data["counts"], data["elevation"], data["reflectance"])
        return dict(data, labels=labels)

    # ------------------------------------------------------------------
    def as_pipeline(self) -> Pipeline:
        return Pipeline(
            [
                Stage("slam", self.stage_slam),
                Stage("transform", self.stage_transform),
                Stage("icp_refine", self.stage_icp_refine),
                Stage("rasterize", self.stage_rasterize),
                Stage("label", self.stage_label),
            ],
            name="mapgen",
        )

    def run(self, dataset: ShardedDataset, fused: bool = True, store=None) -> GridMap:
        data = self.load(dataset)
        pipe = self.as_pipeline()
        out = pipe.run_fused(data) if fused else pipe.run_staged(data, store)
        return GridMap(
            counts=jnp.asarray(out["counts"]),
            elevation=jnp.asarray(out["elevation"]),
            reflectance=jnp.asarray(out["reflectance"]),
            labels=jnp.asarray(out["labels"]),
        ), out

    def pose_error(self, out: dict) -> float:
        """Mean position error of SLAM poses vs ground truth (meters)."""
        est = np.asarray(out["poses"])[:, :2]
        true = np.asarray(out["pose_true"])[:, :2]
        return float(np.mean(np.linalg.norm(est - true, axis=1)))
